"""Training runtime: fault-tolerant loop with integrated online auto-tuning.

Framework integration of the paper's technique: during early steps the
online auto-tuner explores *step-program variants* (attention chunk sizes
— the vectLen/unroll analogues of the compiled train step) under the
regeneration-budget policy, hot-swapping the active jitted step when a
variant measures faster. All overheads are part of the wall-clock the loop
reports, exactly like the paper's "all run-time overheads included".

Tuning is configured by the embedded :class:`~repro.api.TuningConfig`
(``TrainLoopConfig.tuning``) and owned by a
:class:`~repro.api.TuningSession`: the budget is shared with any other
tunable step-programs (and, in kernel modes, the model's constituent
catalog kernels), and the best points are persisted next to the
checkpoints so a restarted (or elastically re-scaled) job warm-starts
instead of re-exploring.

Fault tolerance:
  * checkpoint every ``ckpt_every`` steps (atomic, retained set),
  * auto-resume from the latest checkpoint (data stream is a pure function
    of the step index, so restarts are bit-deterministic),
  * optional injected failure (tests preemption recovery),
  * straggler watchdog: steps slower than ``straggler_factor`` × running
    median are flagged (the single-host analogue of replacing a slow
    worker; the count is reported).
"""

from __future__ import annotations

import contextlib
import dataclasses
import statistics
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.api import (
    KERNEL_TUNING_MODES,
    TuningConfig,
    TuningSession,
    apply_tuning_kwargs,
    install_tuning_aliases,
    train_tuning_defaults,
)
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import (
    Compilette, Evaluator, Param, clamped_options, product_space,
)
from repro.data.pipeline import batches_for, device_put_batch
from repro.distributed.compression import ErrorFeedback
from repro.models.model import build_model
from repro.models.params import init_tree
from repro.optim.adamw import AdamW, OptimizerConfig

# legacy TrainLoopConfig field → TuningConfig field
_TUNING_ALIASES = {
    "autotune": "enabled",
    "tune_max_overhead": "max_overhead",
    "tune_invest": "invest",
    "tune_strategy": "strategy",
    "tune_async": "async_generation",
    "tune_prefetch": "prefetch",
    "compile_workers": "compile_workers",
    "compile_backend": "compile_backend",
    "kernel_tuning": "kernel_tuning",
    "kernel_strategies": "strategies",
}


class TrainLoopConfig:
    """Loop knobs; tuning knobs live in the embedded ``tuning`` config.

    The legacy flat fields (``autotune``, ``tune_strategy``,
    ``tune_async``, …) remain accepted as constructor keywords and
    readable/writable properties, aliasing into ``self.tuning``.
    """

    def __init__(
        self,
        steps: int = 50,
        ckpt_every: int = 20,
        ckpt_dir: str = "/tmp/repro_ckpt",
        keep: int = 3,
        seed: int = 0,
        compress_grads: bool = False,
        straggler_factor: float = 3.0,
        fail_at_step: int | None = None,
        log_every: int = 10,
        tuning: TuningConfig | None = None,
        **legacy: Any,
    ) -> None:
        self.steps = steps
        self.ckpt_every = ckpt_every
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.seed = seed
        self.compress_grads = compress_grads
        self.straggler_factor = straggler_factor
        self.fail_at_step = fail_at_step
        self.log_every = log_every
        self.tuning = tuning if tuning is not None else \
            train_tuning_defaults()
        apply_tuning_kwargs(self.tuning, _TUNING_ALIASES, legacy,
                            "TrainLoopConfig")


install_tuning_aliases(TrainLoopConfig, _TUNING_ALIASES)


class FaultInjected(RuntimeError):
    pass


def _make_step(model, optimizer, ef: ErrorFeedback | None, cfg: ModelConfig):
    def step(params, opt_state, ef_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if ef is not None:
            grads, ef_state = ef.apply(grads, ef_state)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        return loss, params, opt_state, ef_state, gnorm
    return step


def _attention_step_compilette(model_cfg: ModelConfig, model, optimizer,
                               ef, sample_batch, seq: int) -> Compilette:
    """Compilette whose points are attention-chunk program variants.

    Chunk options are bounded by the training sequence length up front
    (same dedup as the serve compilettes): chunks past ``seq`` all
    compile to the same program, so enumerating them would waste the
    shared regeneration budget.
    """
    space = product_space([
        Param("attn_q_chunk", clamped_options((64, 128, 256), seq),
              phase=1, switch_rank=0),
        Param("attn_k_chunk", clamped_options((64, 128, 256, 512), seq),
              phase=1, switch_rank=1),
    ])

    def generate(point, **spec):
        cfg2 = dataclasses.replace(
            model_cfg,
            attn_q_chunk=point["attn_q_chunk"],
            attn_k_chunk=point["attn_k_chunk"],
        )
        model2 = build_model(cfg2)
        raw = _make_step(model2, optimizer, ef, cfg2)
        return jax.jit(raw, donate_argnums=())

    return Compilette("train_step_attn", space, generate,
                      cache_token=repr(model_cfg))


def train(
    model_cfg: ModelConfig,
    shape: ShapeSpec,
    loop: TrainLoopConfig | None = None,
    opt_cfg: OptimizerConfig | None = None,
) -> dict[str, Any]:
    loop = loop or TrainLoopConfig()
    tcfg = loop.tuning
    if tcfg.kernel_tuning not in KERNEL_TUNING_MODES:
        raise ValueError(
            f"kernel_tuning must be off|program|kernel|both, "
            f"got {tcfg.kernel_tuning!r}")
    model = build_model(model_cfg)
    optimizer = AdamW(opt_cfg or OptimizerConfig(warmup_steps=10,
                                                 total_steps=loop.steps))
    ef = ErrorFeedback() if loop.compress_grads else None
    ckpt = Checkpointer(loop.ckpt_dir, keep=loop.keep)
    registry_path = f"{loop.ckpt_dir}/tuned.json"

    # ---- init or resume -------------------------------------------------
    key = jax.random.PRNGKey(loop.seed)
    params = init_tree(model.param_defs(), key, model_cfg.param_dtype)
    opt_state = optimizer.init(params)
    ef_state = ef.init(params) if ef else None
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        skeleton = {"params": params, "opt": opt_state}
        state, manifest = ckpt.restore(skeleton, latest)
        params, opt_state = state["params"], state["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        start_step = manifest["step"]

    # ---- step program (with optional online auto-tuning) ---------------
    stream = batches_for(model_cfg, shape, seed=loop.seed + 1,
                         start_step=start_step)
    first_batch = device_put_batch(next(stream))
    raw_step = jax.jit(_make_step(model, optimizer, ef, model_cfg))

    session = None
    tuner = None
    tune_program = tcfg.tune_program
    tune_kernels = tcfg.tune_kernels
    if tune_program or tune_kernels:
        # One session per training process: a single regeneration budget
        # shared by every tunable step-program AND every constituent
        # kernel, warm-started from the checkpoint-adjacent registry so
        # a restarted job skips re-exploration. Variant jitting overlaps
        # the training steps; a resumed job whose registry warm-start
        # re-proposes known points hits the generation cache instead of
        # re-building the step program.
        if tcfg.registry_path is None:
            tcfg = dataclasses.replace(tcfg, registry_path=registry_path)
        session = TuningSession(tcfg)
    if tune_kernels:
        # Hierarchical registration, kernel level: each Pallas kernel of
        # the step-program tunes as an independent compilette under the
        # shared budget (untunable reduced shapes are skipped).
        B_k, T_k = first_batch["tokens"].shape
        session.attach_kernels(model_cfg, batch=B_k, seq=T_k)
    if tune_program:
        comp = _attention_step_compilette(
            model_cfg, model, optimizer, ef, first_batch, shape.seq_len)
        spec = {"seq": shape.seq_len}
        evaluator = Evaluator(
            mode="real", real_runs=2, warmup=1,
            make_args=lambda: (params, opt_state, ef_state, first_batch))
        tuner = session.register(
            "train_step_attn", comp, evaluator,
            specialization=spec, reference_fn=raw_step,
        )

    # ---- loop ------------------------------------------------------------
    losses: list[float] = []
    durations: list[float] = []
    stragglers = 0
    t_start = time.perf_counter()
    step = start_step
    batch = first_batch
    scope_ctx = session.scope() if session is not None \
        else contextlib.nullcontext()
    with scope_ctx:
        while step < loop.steps:
            if loop.fail_at_step is not None and step == loop.fail_at_step:
                raise FaultInjected(f"injected failure at step {step}")
            t0 = time.perf_counter()
            fn = tuner if tuner is not None else raw_step
            loss, params, opt_state, ef_state, gnorm = fn(
                params, opt_state, ef_state, batch)
            loss = float(loss)
            if session is not None:
                session.maybe_pump()
            dt = time.perf_counter() - t0
            durations.append(dt)
            if len(durations) >= 5:
                med = statistics.median(durations)
                if dt > loop.straggler_factor * med:
                    stragglers += 1
            losses.append(loss)
            step += 1
            if step % loop.ckpt_every == 0 or step == loop.steps:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          extra={"loss": loss})
                if session is not None:
                    session.save()
            batch = device_put_batch(next(stream))

    wall = time.perf_counter() - t_start
    out = {
        "steps": step,
        "start_step": start_step,
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        "wall_s": wall,
        "stragglers_flagged": stragglers,
        "losses": losses,
    }
    if tuner is not None:
        out["autotune"] = tuner.stats()
    if session is not None:
        session.close()
        out["coordinator"] = session.stats()
    return out
