"""Serving-grade tuner lifecycle: bucketing, convergence, eviction.

``TuningCoordinator.register`` is idempotent per (kernel, specialization),
which is what lets tuning pay off across requests — but real serve traffic
has unbounded shape diversity: one tuner per exact (seq, batch) pair
accumulates tuners (and the request arrays their evaluator closures pin)
without bound. The :class:`TunerLifecycle` bounds both dimensions:

  * **power-of-two sequence bucketing** — shape-like specialization keys
    (``seq``, ``max_len``) are rounded to the nearest power of two *in log
    space* (geometric rounding), so prompts of length 120 and 150 share
    the 128-bucket tuner instead of each spawning their own;
  * **convergence** — a tuner whose search strategy has exhausted its
    space moves to ``CONVERGED``: it keeps serving its tuned active
    function, but its evaluator closure (which pins a request's
    params/batch/cache arrays) is released since nothing will be
    evaluated again;
  * **idle eviction** — a tuner not called for ``idle_evict_s`` simulated
    seconds is ``RETIRED``: its best point is flushed to the registry,
    its evaluator closure is released, and it is unregistered from the
    coordinator (its spent/gained accounting is folded into a tombstone
    so the process-wide budget does not inflate when tuners leave).

A retired specialization that comes back simply re-registers; the registry
warm-start re-validates its persisted best with a single regeneration —
and because the coordinator's :class:`~repro.core.GenerationCache` is
owned by the *coordinator*, not the tuner, retirement releases closures
and accounting but NOT compiled variants: the re-registered bucket's
re-validation (and any re-exploration) is a cache hit, never a recompile.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any


class TunerState(enum.Enum):
    ACTIVE = "active"         # exploring (or waiting for budget)
    CONVERGED = "converged"   # space exhausted; still serving its best fn
    RETIRED = "retired"       # evicted: unregistered, closures released


def pow2_bucket(n: int) -> int:
    """Nearest power of two in log space (geometric rounding).

    120 → 128 and 150 → 128 (the midpoint between 128 and 256 is
    sqrt(128*256) ≈ 181), so nearby prompt shapes share one bucket.
    """
    n = int(n)
    if n <= 1:
        return 1
    lo = 1 << (n.bit_length() - 1)
    hi = lo << 1
    # n <= sqrt(lo*hi)  <=>  n*n <= lo*hi  (exact in integers)
    return lo if n * n <= lo * hi else hi


@dataclasses.dataclass
class TunerLifecycle:
    """Policy knobs for the coordinator's managed-tuner lifecycle.

    ``bucket_keys`` names the shape-like specialization keys to bucket;
    ``idle_evict_s`` is the idle time (coordinator-clock seconds) after
    which a tuner is retired, ``None`` disables eviction.
    """

    seq_buckets: bool = True
    bucket_keys: tuple[str, ...] = ("seq", "max_len")
    idle_evict_s: float | None = 300.0

    def bucket_specialization(self, spec: dict[str, Any]) -> dict[str, Any]:
        """Bucketed copy of ``spec`` (identity when bucketing is off)."""
        if not self.seq_buckets:
            return dict(spec)
        out = dict(spec)
        for key in self.bucket_keys:
            v = out.get(key)
            if isinstance(v, int) and not isinstance(v, bool) and v > 0:
                out[key] = pow2_bucket(v)
        return out

    def bucket_length(self, n: int) -> int:
        """Bucketed problem extent (for building bucket-wide compilettes)."""
        return pow2_bucket(n) if self.seq_buckets else int(n)

    def should_evict(self, last_used_s: float, now_s: float) -> bool:
        return (
            self.idle_evict_s is not None
            and now_s - last_used_s >= self.idle_evict_s
        )


def release_evaluator_closure(tuner: Any) -> None:
    """Drop the evaluator's pinned argument factory, if it has one.

    Serve evaluators close over a request's params/batch/cache so
    between-request pumps can measure variants; once a tuner is converged
    or retired nothing will evaluate again — holding those arrays for the
    coordinator's lifetime would be a leak. Evaluators without a
    ``make_args`` factory (e.g. ``VirtualClockEvaluator``) are untouched.
    """
    ev = getattr(tuner, "evaluator", None)
    if ev is not None and getattr(ev, "make_args", None) is not None:
        ev.make_args = None
