"""Kernel-granular tuning plane: coordinator-owned Pallas kernel handles.

The paper's claim is that auto-tuning pays off at the granularity of the
individual short-running kernel; PRs 1–3 built the management machinery
(shared budget, fairness, warm starts, async generation, lifecycle) but
only ever applied it to monolithic step-programs. The
:class:`KernelTuningPlane` closes that gap: it turns every kernel in the
:class:`~repro.kernels.catalog.KernelCatalog` into an independently
managed :class:`~repro.runtime.coordinator.ManagedTuner` —

  * **one handle per (kernel, spec)** — the spec (problem shape, dtype)
    is extracted from live call arguments or registered explicitly from
    model shapes; the coordinator warm-starts and idle-evicts the
    handle exactly like a step-program tuner. Kernel shape dims (M/N/K,
    Tq/Tkv, …) key EXACTLY — a compiled kernel executable only accepts
    its own shapes, so pow2 bucketing cannot alias them the way it
    aliases chunk-clamping step-programs; registration sites bound
    shape diversity by pre-bucketing the extents they derive specs from
    (serve uses ``lifecycle.bucket_length``) and idle eviction retires
    the long tail;
  * **its own strategy** — ``strategies={"matmul": "greedy", ...}`` maps
    kernel names to search-strategy registry names (cf. "Tuning the
    Tuner": the best searcher is kernel-dependent), defaulting to the
    coordinator's strategy;
  * **one shared budget** — kernel handles draw regeneration slots from
    the same :class:`~repro.core.RegenerationPolicy` budget as the
    step-program tuners, so adding per-kernel tuning never multiplies
    the overhead cap;
  * **model integration** — :func:`use_kernel_plane` installs the plane
    in a context variable; ``repro.models.layers`` routes eager kernel
    calls through :meth:`KernelTuningPlane.call` and, inside jitted
    step-program traces, adopts the plane's best-known kernel points
    instead of hard-coded block sizes (:meth:`best_point`).

Pass ``virtual=(VirtualClock, DeviceProfile)`` to price every kernel by
its analytical cost model instead of compiling — the deterministic
backend the tier-1 kernel-plane tests and ``benchmarks/kernel_plane.py``
drive.
"""

from __future__ import annotations

import contextlib
import contextvars
import json
from typing import Any, Callable, Mapping

from repro.core.evaluator import Evaluator
from repro.kernels.catalog import KernelCatalog, KernelCompilette, get_catalog
from repro.runtime.coordinator import ManagedTuner, TuningCoordinator
from repro.runtime.lifecycle import TunerState

__all__ = [
    "KernelTuningPlane",
    "active_plane",
    "parse_kernel_strategies",
    "use_kernel_plane",
]


def _canon(spec: Mapping[str, Any]) -> str:
    return json.dumps(dict(spec), sort_keys=True, separators=(",", ":"))


def parse_kernel_strategies(items: "list[str]") -> dict[str, str] | None:
    """Parse repeated ``KERNEL=STRATEGY`` CLI items, failing fast.

    Both the kernel name (against the discovered catalog) and the
    strategy (against the search-strategy registry) are validated — a
    typo'd kernel would otherwise be silently ignored and the user would
    tune with the default strategy while believing the override is
    active. Shared by ``launch/serve.py`` and ``examples/serve_lm.py``.
    """
    from repro.core.explorer import available_strategies

    out: dict[str, str] = {}
    known = get_catalog().names()
    for item in items:
        name, _, strat = item.partition("=")
        if name not in known:
            raise SystemExit(
                f"--kernel-strategy: unknown kernel {name!r}; "
                f"catalog kernels: {', '.join(known)}")
        if not strat or strat not in available_strategies():
            raise SystemExit(
                f"--kernel-strategy {item!r}: strategy must be one of "
                f"{', '.join(available_strategies())}")
        out[name] = strat
    return out or None


class KernelTuningPlane:
    """Registers catalog kernels as coordinator-managed tuners."""

    def __init__(
        self,
        coordinator: TuningCoordinator,
        *,
        catalog: KernelCatalog | None = None,
        strategies: Mapping[str, str] | None = None,
        interpret: bool = True,
        aot: bool = True,
        virtual: tuple | None = None,
        gen_cost_s: "float | Callable[..., float] | None" = None,
        evaluator_factory: "Callable[[KernelCompilette], Any] | None" = None,
        eval_runs: int = 1,
        adopt_points: bool = True,
        compilette_hook: "Callable[[KernelCompilette], None] | None" = None,
    ) -> None:
        self.coordinator = coordinator
        self.catalog = catalog or get_catalog()
        self.strategies = dict(strategies or {})
        self.interpret = interpret
        self.aot = aot
        self.virtual = virtual
        self.gen_cost_s = gen_cost_s
        self.evaluator_factory = evaluator_factory
        self.eval_runs = eval_runs
        # Runs on every freshly built kernel compilette, before its first
        # generation: the fault-injection replay harness installs scripted
        # gate verdicts (``comp.gate_script``) and wrapped generators here.
        self.compilette_hook = compilette_hook
        # Trace-time adoption: jitted step-programs read best_point() for
        # their block sizes. Turned OFF when a program-level tuner owns
        # those same parameters (serve/train "both" mode), so the two
        # planes never fight over one knob.
        self.adopt_points = adopt_points
        self._handles: dict[tuple[str, str], ManagedTuner] = {}
        # last concrete call arguments per handle: evaluations then
        # measure live traffic, falling back to synthetic example args.
        # Entries are dropped once a handle converges/retires (nothing
        # will evaluate again — keeping them would pin one full set of
        # kernel inputs per shape cell for the coordinator's lifetime).
        self._live_args: dict[tuple[str, str], tuple] = {}
        # hot-path memo: (kernel, arg shapes/dtypes, overrides) → handle,
        # skipping spec extraction + canonicalization + the coordinator
        # register round-trip on every call after the first
        self._fast: dict[tuple, tuple[tuple[str, str], ManagedTuner]] = {}

    @classmethod
    def shared(cls, coordinator: TuningCoordinator,
               **kwargs: Any) -> "KernelTuningPlane":
        """The one plane of ``coordinator``, created on first use.

        A long-lived serving coordinator spans many requests; building a
        fresh plane per request would discard the handle memo and the
        live-args table every time (re-building compilettes only for the
        coordinator's idempotent register to throw them away, and
        pinning evaluators to a dead plane's live-args). Construction
        kwargs apply on first use; the *mutable* config knobs
        (``adopt_points``, ``strategies``) are re-applied on every call,
        so a request that switches tuning mode (kernel ↔ both) cannot
        leave a stale plane fighting a program tuner over one knob.
        """
        plane = getattr(coordinator, "_kernel_plane", None)
        if plane is None:
            plane = cls(coordinator, **kwargs)
            coordinator._kernel_plane = plane
        else:
            if "adopt_points" in kwargs:
                plane.adopt_points = kwargs["adopt_points"]
            if kwargs.get("strategies"):
                plane.strategies.update(kwargs["strategies"])
            if kwargs.get("compilette_hook") is not None:
                plane.compilette_hook = kwargs["compilette_hook"]
        return plane

    # ------------------------------------------------------------ evaluators
    def _evaluator(self, comp: KernelCompilette,
                   key: tuple[str, str]) -> Any:
        if self.evaluator_factory is not None:
            return self.evaluator_factory(comp)

        def make_args() -> tuple:
            live = self._live_args.get(key)
            return live if live is not None else comp.example_call_args()

        return Evaluator(mode="real", real_runs=self.eval_runs, warmup=1,
                         make_args=make_args)

    # ------------------------------------------------------------- handles
    def register_spec(self, name: str, spec: Mapping[str, Any], *,
                      strategy: str | None = None,
                      require: bool = True) -> ManagedTuner | None:
        """Get-or-register the managed tuner for (kernel, spec).

        Idempotent per spec — serve code can re-register on every
        request. Only ``seq``/``max_len``-style keys are bucketed (the
        lifecycle's bucket_keys); kernel shape dims key exactly, since
        the compiled executable is shape-exact — callers that want
        nearby shapes to share a tuner must pre-bucket the extents they
        build the spec from. A handle evicted by the lifecycle
        re-registers transparently and warm-starts from the registry.

        A spec at which every tuning point is a hole (e.g. a reduced
        model whose K is below the smallest block_k) is untunable:
        ``require=True`` raises, ``require=False`` returns ``None`` (the
        serve/train hierarchical registration skips such kernels).
        """
        self.prune_released()
        bucketed = self.coordinator.lifecycle.bucket_specialization(
            dict(spec))
        key = (name, _canon(bucketed))
        handle = self._handles.get(key)
        if handle is not None and handle.state is not TunerState.RETIRED:
            # refresh idle stamp through the coordinator's idempotent path
            return self.coordinator.register(
                name, handle.tuner.compilette, handle.tuner.evaluator,
                specialization=dict(spec))
        comp = self.catalog.compilette(
            name, bucketed,
            interpret=self.interpret, aot=self.aot, virtual=self.virtual,
            gen_cost_s=self.gen_cost_s)
        if self.compilette_hook is not None:
            self.compilette_hook(comp)
        if not comp.has_valid_points():
            if require:
                raise ValueError(
                    f"kernel {name!r} has no valid tuning point at spec "
                    f"{bucketed}")
            return None
        handle = self.coordinator.register(
            name, comp, self._evaluator(comp, key),
            specialization=dict(spec),
            strategy=strategy or self.strategies.get(name))
        handle.plane_managed = True
        self._handles[key] = handle
        return handle

    def handle(self, name: str, *args: Any,
               **spec_overrides: Any) -> ManagedTuner:
        """Managed tuner for a kernel call, spec extracted from ``args``."""
        spec = self.catalog.spec_of(name, *args, **spec_overrides)
        return self.register_spec(name, spec)

    def prune_released(self) -> None:
        """Drop pinned live args of handles that will never evaluate again.

        A CONVERGED/RETIRED tuner never measures — the lifecycle
        releases its evaluator closure for exactly that reason, and the
        plane must not keep pinning the arrays behind its back. Runs on
        every plane use (cheap: a few dict entries), so one kernel's
        continued traffic unpins its converged siblings.
        """
        for key, handle in list(self._handles.items()):
            if (handle.state is not TunerState.ACTIVE
                    or handle.tuner.explorer.finished):
                self._live_args.pop(key, None)

    def _remember_or_release(self, key: tuple[str, str],
                             handle: ManagedTuner, args: tuple) -> None:
        """Keep live args only while the handle can still evaluate."""
        if (handle.state is TunerState.ACTIVE
                and not handle.tuner.explorer.finished):
            self._live_args[key] = args
        else:
            self._live_args.pop(key, None)

    def call(self, name: str, *args: Any, **spec_overrides: Any) -> Any:
        """Run a kernel through its coordinator-managed active function.

        Live arguments are remembered FIRST, so the register-time
        reference measurement (and all later evaluations, until the
        lifecycle releases the closure) runs on real traffic. Returns
        ``None`` when the spec is untunable (every point a hole) — the
        calling layer falls back to its plain implementation.
        """
        fast_key = (
            name,
            tuple((tuple(a.shape), str(a.dtype)) for a in args
                  if hasattr(a, "shape")),
            tuple(sorted(spec_overrides.items())),
        )
        memo = self._fast.get(fast_key)
        if memo is not None:
            key, handle = memo
            if handle.state is not TunerState.RETIRED:
                # hot path: no spec extraction, no canonicalization, no
                # coordinator lock (the handle call refreshes last_used)
                self._remember_or_release(key, handle, args)
                return handle(*args)
            self._fast.pop(fast_key, None)
            self._live_args.pop(key, None)
        self.prune_released()
        spec = self.catalog.spec_of(name, *args, **spec_overrides)
        bucketed = self.coordinator.lifecycle.bucket_specialization(spec)
        key = (name, _canon(bucketed))
        self._live_args[key] = args
        handle = self.register_spec(name, spec, require=False)
        if handle is None:
            self._live_args.pop(key, None)
            return None
        self._fast[fast_key] = (key, handle)
        self._remember_or_release(key, handle, args)
        return handle(*args)

    # -------------------------------------------------------------- lookup
    def handles(self, name: str | None = None) -> list[ManagedTuner]:
        out = [m for (n, _), m in self._handles.items()
               if name is None or n == name]
        return [m for m in out if m.state is not TunerState.RETIRED]

    def best_point(self, name: str,
                   spec: Mapping[str, Any] | None = None) -> dict | None:
        """Best-known tuned point for ``name`` (for trace-time adoption).

        With ``spec``, the exact bucketed handle is consulted; otherwise
        the most-called handle of that kernel (the shape that dominates
        live traffic) answers. ``None`` until something was measured.
        """
        if spec is not None:
            bucketed = self.coordinator.lifecycle.bucket_specialization(
                dict(spec))
            m = self._handles.get((name, _canon(bucketed)))
            candidates = [m] if m is not None else []
        else:
            candidates = sorted(
                self.handles(name),
                key=lambda m: -m.tuner.accounts.kernel_calls)
        for m in candidates:
            best = m.tuner.explorer.best_point
            if best is not None:
                return dict(best)
        return None

    def stats(self) -> dict[str, Any]:
        return {
            "handles": {
                f"{n}@{spec}": m.stats()
                for (n, spec), m in self._handles.items()
            },
        }


# ----------------------------------------------------------- active plane
_ACTIVE: "contextvars.ContextVar[KernelTuningPlane | None]" = (
    contextvars.ContextVar("kernel_tuning_plane", default=None))


def active_plane() -> KernelTuningPlane | None:
    """The plane installed by :func:`use_kernel_plane`, if any."""
    return _ACTIVE.get()


@contextlib.contextmanager
def use_kernel_plane(plane: KernelTuningPlane | None):
    """Install ``plane`` for model code (layers) to route kernels through."""
    token = _ACTIVE.set(plane)
    try:
        yield plane
    finally:
        _ACTIVE.reset(token)
