"""Process-wide tuning coordinator: one budget, many kernels, warm starts.

The paper tunes ONE kernel per process with its own regeneration budget.
A production process (training loop, serving binary) runs MANY tunable
step-programs — prefill, decode, the train step, individual Pallas
kernels — and restarts or scales out constantly. The coordinator extends
the paper's economics across both dimensions:

  * **one budget for the whole process** — a single
    :class:`RegenerationPolicy` is applied to the *sum* of tuning time
    spent and time gained across every managed autotuner, so adding more
    tunable kernels never multiplies the tuning overhead cap;
  * **fairness by estimated gain** — each scheduling slot goes to the
    kernel with the highest estimated return per regeneration
    (unmeasured kernels first, then ``potential_gain x call_rate /
    regenerations``), so a hot kernel with headroom gets tuned before a
    cold one that is already optimal;
  * **warm starts from the registry** — every autotuner is seeded from
    the :class:`TunedRegistry` under (kernel, specialization, device
    fingerprint); a restarted or elastically re-scaled job re-validates
    its persisted best variant with a single regeneration instead of
    re-exploring the space (cf. the Kernel Tuning Toolkit's persistent
    dynamic-autotuning service, arXiv:1910.08498);
  * **one tuning thread per process** — instead of one thread per
    kernel, a single coordinator thread (or cooperative ``maybe_pump``
    calls on the hot path) drives every managed autotuner.

Time is read through an injectable ``clock`` (default
``time.perf_counter``); with a :class:`~repro.core.VirtualClock` the
whole scheduler is deterministic, which is how the tier-1 tests drive it.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable, Sequence

from repro.core.autotuner import OnlineAutotuner
from repro.core.compilette import Compilette
from repro.core.decision import RegenerationPolicy, TuningAccounts
from repro.core.persistence import TunedRegistry
from repro.core.tuning_space import Point


def device_fingerprint() -> str:
    """Stable identity of the accelerator the process is tuning for.

    Tuned points are only transferable between identical devices, so the
    registry key includes this fingerprint.
    """
    try:
        import jax

        d = jax.devices()[0]
        return f"{d.platform}:{d.device_kind}"
    except Exception:
        return "unknown"


def _canon_spec(spec: dict[str, Any]) -> str:
    return json.dumps(spec, sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass
class ManagedTuner:
    """One kernel/step-program under coordinator management."""

    name: str
    specialization: dict[str, Any]
    tuner: OnlineAutotuner
    warm_started: bool
    calls_at_last_wake: int = 0

    def __call__(self, *args: Any) -> Any:
        return self.tuner(*args)

    @property
    def active_fn(self) -> Callable[..., Any]:
        return self.tuner.active_fn

    def stats(self) -> dict[str, Any]:
        out = self.tuner.stats()
        out["warm_started"] = self.warm_started
        return out


class TuningCoordinator:
    """Owns every :class:`OnlineAutotuner` of a process.

    ``register`` is idempotent per (name, specialization): serving code
    can re-register on every request and always gets the same managed
    autotuner back, which is what makes tuning pay off *across* requests.
    """

    def __init__(
        self,
        *,
        policy: RegenerationPolicy | None = None,
        registry: TunedRegistry | None = None,
        registry_path: str | None = None,
        device: str | None = None,
        clock: Callable[[], float] | None = None,
        pump_every: int = 8,
    ) -> None:
        self.policy = policy or RegenerationPolicy()
        self.clock = clock or time.perf_counter
        if registry is not None:
            self.registry = registry
        elif registry_path is not None:
            self.registry = TunedRegistry.load(registry_path)
        else:
            self.registry = TunedRegistry()
        self.registry_path = registry_path
        self.device = device or device_fingerprint()
        self.app_start_s = self.clock()
        self.pump_every = max(int(pump_every), 1)
        self._managed: list[ManagedTuner] = []
        self._by_key: dict[tuple[str, str], ManagedTuner] = {}
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._app_calls = 0

    # ------------------------------------------------------------ register
    def register(
        self,
        name: str,
        compilette: Compilette,
        evaluator: Any,
        *,
        specialization: dict[str, Any] | None = None,
        reference_fn: Callable[..., Any] | None = None,
        reference_score_s: float | None = None,
    ) -> ManagedTuner:
        spec = dict(specialization or {})
        key = (name, _canon_spec(spec))
        with self._lock:
            existing = self._by_key.get(key)
            if existing is not None:
                return existing
            warm_point = self.registry.get(name, spec, self.device)
            if warm_point is None and ":" in self.device:
                # pre-coordinator registries keyed by bare device_kind
                warm_point = self.registry.get(
                    name, spec, self.device.split(":", 1)[1])
            if warm_point is not None and not compilette.space.contains(
                    warm_point):
                # stale entry from an older space definition (renamed or
                # added parameters): a cache miss, never a crash
                warm_point = None
            tuner = OnlineAutotuner(
                compilette,
                evaluator,
                policy=self.policy,
                specialization=spec,
                reference_fn=reference_fn,
                reference_score_s=reference_score_s,
                base_point=warm_point,
                seed_points=[warm_point] if warm_point else (),
                wake_every=None,           # managed: coordinator schedules
                clock=self.clock,
                budget_gate=self._shared_budget_gate,
            )
            managed = ManagedTuner(
                name=name,
                specialization=spec,
                tuner=tuner,
                warm_started=warm_point is not None,
            )
            self._managed.append(managed)
            self._by_key[key] = managed
            return managed

    # ------------------------------------------------------- shared budget
    def _aggregate_accounts(self) -> TuningAccounts:
        agg = TuningAccounts(app_start_s=self.app_start_s)
        for m in self._managed:
            t = m.tuner
            t._update_gains()
            agg.tuning_spent_s += t.accounts.tuning_spent_s
            agg.gained_s += t.accounts.gained_s
            agg.kernel_calls += t.accounts.kernel_calls
            agg.regenerations += t.accounts.regenerations
            agg.swaps += t.accounts.swaps
            agg.init_spent_s += t.accounts.init_spent_s
        return agg

    def _shared_budget_gate(
        self, _caller: TuningAccounts, now_s: float, estimate_s: float
    ) -> bool:
        """Regeneration gate applied to the PROCESS totals, not the caller.

        Every managed autotuner defers here, so the overhead cap bounds
        the sum of all tuning time while gains found by one kernel can
        fund exploration of another.
        """
        return self.policy.should_regenerate(
            self._aggregate_accounts(), now_s, estimate_s
        )

    # ----------------------------------------------------------- schedule
    def _priority(self, m: ManagedTuner) -> float:
        """Estimated return of granting this kernel the next slot."""
        t = m.tuner
        if t.explorer.finished:
            return float("-inf")
        if t.accounts.regenerations == 0:
            # Nothing measured yet: exploration has unbounded information
            # value; bootstrap in registration order.
            return float("inf")
        calls_since = t.accounts.kernel_calls - m.calls_at_last_wake
        potential = max(
            t.reference_score_s - max(t.explorer.best_score, 0.0), 0.0
        )
        # gain-rate estimate, damped by how much we already invested here
        return (potential * (1.0 + calls_since)) / (
            1.0 + t.accounts.regenerations
        )

    def _pick(self) -> ManagedTuner | None:
        best: ManagedTuner | None = None
        best_pri = float("-inf")
        for m in self._managed:   # registration order breaks ties
            pri = self._priority(m)
            if pri > best_pri:
                best, best_pri = m, pri
        if best_pri == float("-inf"):
            return None
        return best

    def pump(self) -> bool:
        """One scheduling slot: pick the best kernel and wake it.

        Returns True when the wake swapped in a faster variant.
        """
        with self._lock:
            m = self._pick()
        if m is None:
            return False
        regens_before = m.tuner.accounts.regenerations
        swapped = m.tuner.wake()
        if m.tuner.accounts.regenerations == regens_before:
            # budget-denied (or space exhausted): the slot did nothing, so
            # leave the kernel's hotness signal intact — resetting it here
            # would starve exactly the kernel we judged most valuable.
            return False
        m.calls_at_last_wake = m.tuner.accounts.kernel_calls
        best = m.tuner.explorer.best_point
        if best is not None:
            self.registry.put(
                m.name, m.specialization, self.device,
                best, m.tuner.explorer.best_score,
            )
        return swapped

    def maybe_pump(self) -> bool:
        """Cooperative pacing: call once per application step/iteration."""
        self._app_calls += 1
        if self._thread is not None:
            return False
        if self._app_calls % self.pump_every:
            return False
        return self.pump()

    @property
    def finished(self) -> bool:
        return all(m.tuner.explorer.finished for m in self._managed)

    # ------------------------------------------------------------ threaded
    def start_thread(self, wake_period_s: float = 0.002) -> None:
        """Single per-process tuning thread (replaces one thread/kernel)."""
        if self._thread is not None:
            return

        def _loop() -> None:
            while not self._stop.is_set():
                self.pump()
                if self.finished:
                    break
                self._stop.wait(wake_period_s)

        self._thread = threading.Thread(
            target=_loop, daemon=True, name="tuning-coordinator"
        )
        self._thread.start()

    def stop_thread(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        self._stop = threading.Event()

    # --------------------------------------------------------- persistence
    def save_registry(self, path: str | None = None) -> None:
        path = path or self.registry_path
        if path is None:
            return
        # flush current bests before writing
        for m in self._managed:
            best = m.tuner.explorer.best_point
            if best is not None:
                self.registry.put(
                    m.name, m.specialization, self.device,
                    best, m.tuner.explorer.best_score,
                )
        self.registry.save(path)

    def close(self) -> None:
        self.stop_thread()
        self.save_registry()

    # ------------------------------------------------------------- reports
    def stats(self) -> dict[str, Any]:
        agg = self._aggregate_accounts()
        elapsed = self.clock() - self.app_start_s
        return {
            "device": self.device,
            "n_kernels": len(self._managed),
            "regenerations": agg.regenerations,
            "swaps": agg.swaps,
            "tuning_spent_s": agg.tuning_spent_s,
            "gained_s": agg.gained_s,
            "overhead_frac": (
                agg.tuning_spent_s / elapsed if elapsed > 0 else 0.0
            ),
            "budget_s": self.policy.budget_s(agg, self.clock()),
            "kernels": self._kernel_stats(),
        }

    def _kernel_stats(self) -> dict[str, dict[str, Any]]:
        out: dict[str, dict[str, Any]] = {}
        for m in self._managed:
            key = m.name
            if key in out:   # same kernel, different specialization
                key = f"{m.name}@{_canon_spec(m.specialization)}"
            out[key] = m.stats()
        return out
