"""Process-wide tuning coordinator: one budget, many kernels, warm starts.

The paper tunes ONE kernel per process with its own regeneration budget.
A production process (training loop, serving binary) runs MANY tunable
step-programs — prefill, decode, the train step, individual Pallas
kernels — and restarts or scales out constantly. The coordinator extends
the paper's economics across both dimensions:

  * **one budget for the whole process** — a single
    :class:`RegenerationPolicy` is applied to the *sum* of tuning time
    spent and time gained across every managed autotuner, so adding more
    tunable kernels never multiplies the tuning overhead cap;
  * **fairness by estimated gain** — each scheduling slot goes to the
    kernel with the highest estimated return per regeneration
    (unmeasured kernels first, then ``potential_gain x call_rate /
    regenerations``), so a hot kernel with headroom gets tuned before a
    cold one that is already optimal;
  * **warm starts from the registry** — every autotuner is seeded from
    the :class:`TunedRegistry` under (kernel, specialization, device
    fingerprint); a restarted or elastically re-scaled job re-validates
    its persisted best variant with a single regeneration instead of
    re-exploring the space (cf. the Kernel Tuning Toolkit's persistent
    dynamic-autotuning service, arXiv:1910.08498);
  * **one tuning thread per process** — instead of one thread per
    kernel, a single coordinator thread (or cooperative ``maybe_pump``
    calls on the hot path) drives every managed autotuner;
  * **double-buffered variant generation** — with ``async_generation``
    on, a background :class:`~repro.core.CompileFarm` of
    ``compile_workers`` workers compiles candidates while the current
    active functions keep serving (the paper's "new version in a code
    buffer", scaled to M buffers), scheduled by the same gain priority
    ``pump`` uses and capped per kernel so one wide space cannot starve
    the rest; every generation goes through a process-wide
    :class:`~repro.core.GenerationCache` (a point revisited after
    bucketing, eviction or warm start never recompiles), and the
    scheduler prefetch-compiles the next ``prefetch`` proposals of each
    kernel it serves (``SearchStrategy.peek``). Generation time is
    charged to the shared budget in full either way — only the hot-path
    *stall* (``gen_stall_s``) disappears;
  * **a managed lifecycle** — a :class:`~repro.runtime.lifecycle.TunerLifecycle`
    buckets shape-like specializations (so varied prompt lengths share
    tuners), marks exhausted tuners ``CONVERGED`` (releasing their pinned
    evaluator closures) and ``RETIRED``\\ s idle ones, unregistering them
    while folding their accounting into a tombstone so the shared budget
    stays honest.

Time is read through an injectable ``clock`` (default
``time.perf_counter``); with a :class:`~repro.core.VirtualClock` the
whole scheduler is deterministic, which is how the tier-1 tests drive it.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable

from repro.core.autotuner import OnlineAutotuner
from repro.core.compile_farm import CompileFarm
from repro.core.compilette import (
    Compilette,
    GenerationCache,
    GenerationTicket,
)
from repro.core.decision import RegenerationPolicy, TuningAccounts
from repro.core.explorer import SearchStrategy
from repro.core.gate import GATE_MODES, VariantGate
from repro.core.persistence import TunedRegistry, device_fingerprint
from repro.core.transfer import (
    calibrated_traits,
    device_traits,
    transfer_seeds,
)
from repro.runtime.lifecycle import (
    TunerLifecycle,
    TunerState,
    release_evaluator_closure,
)

__all__ = [
    "ManagedTuner",
    "TuningCoordinator",
    "device_fingerprint",   # re-export: pre-refactor import site
]


def _canon_spec(spec: dict[str, Any]) -> str:
    return json.dumps(spec, sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass(eq=False)   # identity semantics: hashable handle
class ManagedTuner:
    """One kernel/step-program under coordinator management."""

    name: str
    specialization: dict[str, Any]
    tuner: OnlineAutotuner
    warm_started: bool
    clock: Callable[[], float] = time.perf_counter
    state: TunerState = TunerState.ACTIVE
    last_used_s: float = 0.0
    calls_at_last_wake: int = 0
    # persistence key device: the coordinator's device fingerprint plus
    # the compilette's own identity suffix (e.g. the kernel source hash),
    # so editing a kernel invalidates exactly that kernel's warm starts
    registry_device: str = ""
    # set by the KernelTuningPlane: this tuner is an individual kernel
    # compilette (vs a whole step-program); consumers (CLI reports) can
    # split stats() entries without hard-coding step-program names
    plane_managed: bool = False
    # fleet sync cursor: how much of the explorer history has already
    # been published to the registry's evaluation ledger
    evals_flushed: int = 0
    # transfer plane: the trait vector persisted with this tuner's bests
    # (None when the device cannot describe itself), and the space keys
    # of foreign bests injected as transfer seeds at registration
    device_traits: dict[str, float] | None = None
    transfer_seed_keys: tuple = ()

    def __call__(self, *args: Any) -> Any:
        t0 = self.last_used_s = self.clock()
        out = self.tuner(*args)
        # Real per-call latency telemetry: the EWMA this feeds is what the
        # LatencyHeadroomGate reads, so one outlier call (GC pause, first
        # compile) cannot freeze or unfreeze tuning by itself.
        self.tuner.observe_latency(self.clock() - t0)
        return out

    @property
    def active_fn(self) -> Callable[..., Any]:
        return self.tuner.active_fn

    def stats(self) -> dict[str, Any]:
        out = self.tuner.stats()
        out["warm_started"] = self.warm_started
        out["state"] = self.state.value
        out["plane_managed"] = self.plane_managed
        out["transfer_seeds"] = len(self.transfer_seed_keys)
        return out


class TuningCoordinator:
    """Owns every :class:`OnlineAutotuner` of a process.

    ``register`` is idempotent per (name, specialization): serving code
    can re-register on every request and always gets the same managed
    autotuner back, which is what makes tuning pay off *across* requests.
    """

    def __init__(
        self,
        *,
        policy: RegenerationPolicy | None = None,
        registry: TunedRegistry | None = None,
        registry_path: str | None = None,
        device: str | None = None,
        clock: Callable[[], float] | None = None,
        pump_every: int = 8,
        lifecycle: TunerLifecycle | None = None,
        strategy: str = "two_phase",
        async_generation: "bool | str" = False,
        generation_cache: GenerationCache | None = None,
        prefetch: int = 1,
        compile_workers: "int | str" = 1,
        gate_mode: str = "off",
        canary_fraction: float = 0.25,
        canary_calls: int = 8,
        gate_rtol: float | None = None,
        gate_atol: float | None = None,
        replica_id: int = 0,
        replica_count: int = 1,
        registry_backend: Any | None = None,
        sync_every_s: float | None = 1.0,
        transfer: bool = False,
        transfer_top_k: int = 3,
        min_similarity: float = 0.75,
    ) -> None:
        if gate_mode not in GATE_MODES:
            raise ValueError(
                f"gate_mode must be one of {GATE_MODES}, got {gate_mode!r}")
        self.policy = policy or RegenerationPolicy()
        # Trusted swaps: with gate_mode != "off" every registered tuner
        # gets a VariantGate over its compilette's declared oracle (with
        # these session-level tolerance overrides) and a quarantine
        # callback writing condemned points through to the registry, so a
        # bad point is never re-trusted across restarts.
        self.gate_mode = gate_mode
        self.canary_fraction = float(canary_fraction)
        self.canary_calls = int(canary_calls)
        self.gate_rtol = gate_rtol
        self.gate_atol = gate_atol
        self.clock = clock or time.perf_counter
        if registry is not None:
            self.registry = registry
        elif registry_path is not None:
            self.registry = TunedRegistry.load(registry_path)
        else:
            self.registry = TunedRegistry()
        self.registry_path = registry_path
        self.device = device or device_fingerprint()
        self.app_start_s = self.clock()
        self.pump_every = max(int(pump_every), 1)
        # Default lifecycle: no bucketing, no eviction (training jobs have
        # a handful of fixed-shape step-programs); serving passes an
        # active TunerLifecycle. Convergence handling is always on.
        self.lifecycle = lifecycle or TunerLifecycle(
            seq_buckets=False, idle_evict_s=None)
        # Names only: the coordinator builds ONE strategy instance per
        # registered tuner (over that tuner's space, seeded from the
        # registry). A shared pre-built instance would leak one kernel's
        # points/seen-set into another and silently drop warm starts.
        if not isinstance(strategy, str):
            raise TypeError(
                "TuningCoordinator strategy must be a registry name "
                f"(one of the repro.core.explorer strategies), got "
                f"{type(strategy).__name__}; pass pre-built instances via "
                "OnlineAutotuner(explorer=...) outside the coordinator")
        self.strategy = strategy
        # Compiled-variant cache: one per coordinator (= per process under
        # the one-coordinator-per-process regime), shared across every
        # managed tuner and SURVIVING tuner retirement, so re-registered
        # buckets and warm starts never recompile. Inject a shared
        # instance to span multiple coordinators. The default is a
        # BOUNDED LRU: compiled executables pin device memory, and an
        # unbounded cache would undo the lifecycle's memory bounding.
        # ("is not None", not truthiness: an EMPTY injected cache is falsy
        # through __len__ but must still be adopted, or two coordinators
        # meant to share one cache would silently get private ones)
        self.generation_cache = (
            generation_cache if generation_cache is not None
            else GenerationCache(max_entries=256))
        # Double-buffered generation: one background compile farm for the
        # whole process, with ``compile_workers`` workers draining the
        # gain-priority queue. True picks the mode from the clock — a
        # virtual (advanceable) clock gets the deterministic "manual"
        # pipeline (one batch of up to ``workers`` jobs completes at the
        # next pump, no sleeps), a real clock gets worker threads. Pass
        # "thread"/"manual"/"process" to force one. The per-kernel cap —
        # a kernel's own request plus its prefetch quota — keeps one
        # kernel's wide space from flooding the farm.
        self.prefetch = max(int(prefetch), 0)
        if async_generation:
            mode = (async_generation if isinstance(async_generation, str)
                    else ("manual" if hasattr(self.clock, "advance")
                          else "thread"))
            self.generator: CompileFarm | None = CompileFarm(
                mode=mode, workers=compile_workers,
                per_kernel_cap=self.prefetch + 1)
        else:
            self.generator = None
        # Fleet fabric: N replicas share one RegistryBackend. Exploration
        # is hash-striped across them (every registered strategy gets
        # partition(replica_id, replica_count)), sync_fleet() publishes
        # local bests/evaluations/quarantines and adopts the fleet's —
        # peer bests enter as CANDIDATE through the normal gate/canary
        # path, peer quarantine is adopted unconditionally, peer
        # evaluations count as seen so no point is compiled twice per
        # fleet. sync_every_s=None syncs on every pump.
        self.replica_id = int(replica_id)
        self.replica_count = max(int(replica_count), 1)
        if not 0 <= self.replica_id < self.replica_count:
            raise ValueError(
                f"replica_id must be in [0, {self.replica_count}), "
                f"got {replica_id}")
        self.registry_backend = registry_backend
        self.sync_every_s = sync_every_s
        self.fleet_syncs = 0
        # Transfer plane: on a fingerprint miss, seed the search with the
        # top-k foreign bests whose device traits are within the
        # similarity floor. Seeds enter via inject_candidate — CANDIDATE
        # through gate/canary, never a blind incumbent.
        self.transfer = bool(transfer)
        self.transfer_top_k = int(transfer_top_k)
        if self.transfer_top_k < 1:
            raise ValueError(
                f"transfer_top_k must be >= 1, got {transfer_top_k}")
        self.min_similarity = float(min_similarity)
        if not 0.0 < self.min_similarity <= 1.0:
            raise ValueError(
                f"min_similarity must be in (0, 1], got {min_similarity}")
        self.transfer_hits = 0
        self._last_sync_s: float | None = None
        self._managed: list[ManagedTuner] = []
        self._by_key: dict[tuple[str, str], ManagedTuner] = {}
        # Accounting tombstone for retired tuners: the shared budget must
        # keep counting what they spent/gained after they unregister.
        self._retired_accounts = TuningAccounts()
        self._n_retired = 0
        # Busy time observed OUTSIDE managed tuners (observe_busy): a
        # kernel-granular serve process runs its step-programs unmanaged,
        # yet that is exactly the useful work a busy-time budget should
        # accrue from — without it, per-kernel tuning would be starved
        # forever (managed kernels are evaluated, never "called").
        self._external_busy_s = 0.0
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._app_calls = 0
        if self.registry_backend is not None:
            # adopt the fleet's published state up front so the very
            # first register() warm-starts from peer bests and never
            # proposes a peer-condemned or peer-evaluated point
            self.sync_fleet()
            self._last_sync_s = self.clock()

    # ------------------------------------------------------------ register
    def register(
        self,
        name: str,
        compilette: Compilette,
        evaluator: Any,
        *,
        specialization: dict[str, Any] | None = None,
        reference_fn: Callable[..., Any] | None = None,
        reference_score_s: float | None = None,
        strategy: str | None = None,
    ) -> ManagedTuner:
        if strategy is not None and not isinstance(strategy, str):
            raise TypeError(
                "register() strategy must be a registry name; a pre-built "
                "instance cannot be re-seeded from the warm-start registry")
        # Shape-like specialization keys are bucketed BEFORE keying, so
        # e.g. seq 120 and seq 150 resolve to one shared 128-bucket tuner.
        spec = self.lifecycle.bucket_specialization(dict(specialization or {}))
        key = (name, _canon_spec(spec))
        with self._lock:
            existing = self._by_key.get(key)
            if existing is not None:
                existing.last_used_s = self.clock()
                return existing
            # Persistence fingerprint: the process device key plus any
            # compilette-declared identity (KernelCompilette appends
            # "src-<hash>" of its ops.py). Editing a kernel's source
            # changes the exact key, so its stale bests miss and exactly
            # that kernel retunes; the legacy fallback chain only ever
            # reaches pre-fingerprint 1–2 part keys, never another hash.
            extra = getattr(compilette, "fingerprint_extra", None)
            reg_device = f"{self.device}:{extra}" if extra else self.device
            # exact fingerprint (incl. compiler version), then legacy keys
            warm_point = self.registry.get_warm(name, spec, reg_device)
            if warm_point is not None and not compilette.space.contains(
                    warm_point):
                # stale entry from an older space definition (renamed or
                # added parameters): a cache miss, never a crash
                warm_point = None
            # persisted quarantine: condemned points (wrong output, tail
            # regression, raising variant) must neither warm-start nor be
            # re-proposed after restart — seed the explorer's quarantine
            # set below and drop a condemned warm point outright
            bad_points = [
                p for p in self.registry.quarantined_points(
                    name, spec, reg_device)
                if compilette.space.contains(p)
            ]
            if warm_point is not None and any(
                    compilette.space.key(warm_point)
                    == compilette.space.key(p) for p in bad_points):
                warm_point = None
            # every generation (sync or async) goes through the shared
            # compiled-variant cache, keyed under this process's device
            compilette.attach_cache(self.generation_cache, self.device)
            gate = (VariantGate(compilette, rtol=self.gate_rtol,
                                atol=self.gate_atol)
                    if self.gate_mode != "off" else None)

            def _quarantine_cb(point: dict[str, Any], reason: str,
                               _name: str = name,
                               _spec: dict[str, Any] = spec,
                               _dev: str = reg_device) -> None:
                self.registry.quarantine(_name, _spec, _dev, point, reason)

            tuner = OnlineAutotuner(
                compilette,
                evaluator,
                policy=self.policy,
                specialization=spec,
                reference_fn=reference_fn,
                reference_score_s=reference_score_s,
                base_point=warm_point,
                seed_points=[warm_point] if warm_point else (),
                wake_every=None,           # managed: coordinator schedules
                strategy=strategy if strategy is not None else self.strategy,
                clock=self.clock,
                budget_gate=self._shared_budget_gate,
                generator=self.generator,
                gate=gate,
                gate_mode=self.gate_mode,
                canary_fraction=self.canary_fraction,
                canary_calls=self.canary_calls,
                quarantine_cb=_quarantine_cb,
            )
            for p in bad_points:
                tuner.explorer.quarantine(p)
            if self.replica_count > 1:
                # fleet: this replica only explores its hash stripe of
                # the space (the warm-start seed stays exempt — the
                # fleet best must re-validate locally through the gate)
                tuner.explorer.partition(self.replica_id, self.replica_count)
            if self.registry_backend is not None:
                # evaluations any replica already published count as
                # seen: never compiled twice per fleet, across restarts
                # too. The warm seed is excluded — marking it seen would
                # swallow its re-validation proposal.
                warm_key = (compilette.space.key(warm_point)
                            if warm_point is not None else None)
                for p in self.registry.evaluated_points(
                        name, spec, reg_device):
                    if not compilette.space.contains(p):
                        continue
                    if (warm_key is not None
                            and compilette.space.key(p) == warm_key):
                        continue
                    tuner.explorer.mark_seen(p)
            # Device traits: what this device IS, persisted with every
            # best so dissimilar-fingerprint peers can rank it. Virtual
            # backends derive them from the exact profile; real ones from
            # the platform fingerprint refined by a cost-model probe
            # against the measured reference time.
            traits = device_traits(compilette, device=self.device)
            traits = calibrated_traits(
                traits, compilette, spec, tuner.reference_score_s,
                device=self.device)
            # Transfer seeds: on a fingerprint miss, the nearest-
            # fingerprint lookup proposes the top-k foreign bests. They
            # jump the proposal queue stripe-exempt (like warm seeds) but
            # flow through generate/evaluate/gate/canary as CANDIDATEs —
            # a foreign best is never trusted blind, and one condemned
            # anywhere in the fleet was already dropped by the lookup or
            # is refused by the explorer's quarantine here.
            seed_keys: list = []
            if self.transfer and warm_point is None and traits is not None:
                for seed in transfer_seeds(
                        self.registry, name, spec, reg_device, traits,
                        top_k=self.transfer_top_k,
                        min_similarity=self.min_similarity):
                    if tuner.explorer.inject_candidate(seed.point):
                        seed_keys.append(
                            compilette.space.key(seed.point))
                        self.transfer_hits += 1
            managed = ManagedTuner(
                name=name,
                specialization=spec,
                tuner=tuner,
                warm_started=warm_point is not None,
                clock=self.clock,
                last_used_s=self.clock(),
                registry_device=reg_device,
                device_traits=traits.to_dict() if traits else None,
                transfer_seed_keys=tuple(seed_keys),
            )
            self._managed.append(managed)
            self._by_key[key] = managed
            return managed

    # ------------------------------------------------------- shared budget
    # TuningAccounts fields summed across tuners by the shared budget
    # (observed_call_s is deliberately NOT additive: it is a per-kernel
    # latency — see _shared_budget_gate — and only max'd for reporting).
    _ADDITIVE_FIELDS = (
        "tuning_spent_s", "gen_spent_s", "gen_stall_s", "eval_spent_s",
        "gained_s", "busy_s", "kernel_calls", "regenerations",
        "gen_requests", "swaps", "init_spent_s",
        "gate_spent_s", "gate_checks", "gate_failures",
        "canary_calls", "canary_promotions", "rollbacks", "quarantined",
    )

    @classmethod
    def _accumulate(cls, dst: TuningAccounts, src: TuningAccounts) -> None:
        for f in cls._ADDITIVE_FIELDS:
            setattr(dst, f, getattr(dst, f) + getattr(src, f))
        dst.observed_call_s = max(dst.observed_call_s, src.observed_call_s)
        dst.observed_tail_s = max(dst.observed_tail_s, src.observed_tail_s)

    def observe_busy(self, seconds: float) -> None:
        """Credit useful work done outside any managed tuner.

        Serving loops call this with the step-program time when the step
        itself is NOT coordinator-managed (``kernel_tuning="kernel"``):
        a ``budget_from="busy"`` policy then accrues budget from real
        traffic exactly as it would had the step been a managed tuner.
        Callers must not double-report work a ManagedTuner already
        counts (its calls accrue ``busy_s`` via calls × score).
        """
        if seconds > 0:
            self._external_busy_s += float(seconds)

    def _aggregate_accounts(self) -> TuningAccounts:
        agg = TuningAccounts(app_start_s=self.app_start_s)
        self._accumulate(agg, self._retired_accounts)
        for m in self._managed:
            m.tuner._update_gains()
            self._accumulate(agg, m.tuner.accounts)
        agg.busy_s += self._external_busy_s
        return agg

    def _shared_budget_gate(
        self, caller: TuningAccounts, now_s: float, estimate_s: float
    ) -> bool:
        """Budget gate on the PROCESS totals; headroom gate on the CALLER.

        Every managed autotuner defers here, so the overhead cap bounds
        the sum of all tuning time while gains found by one kernel can
        fund exploration of another. The latency-headroom gate is the
        exception: SLO headroom is a per-kernel property, so it reads the
        calling tuner's own observed per-call time — a slow prefill must
        not veto tuning of a fast decode step (nor vice versa).
        """
        if not self.policy.headroom_allows(caller, estimate_s):
            return False
        return self.policy.budget_allows(
            self._aggregate_accounts(), now_s, estimate_s
        )

    # ----------------------------------------------------------- schedule
    def _priority(self, m: ManagedTuner) -> float:
        """Estimated return of granting this kernel the next slot."""
        t = m.tuner
        if m.state is not TunerState.ACTIVE or t.explorer.finished:
            return float("-inf")
        if t.accounts.regenerations == 0:
            # Nothing measured yet: exploration has unbounded information
            # value; bootstrap in registration order.
            return float("inf")
        calls_since = t.accounts.kernel_calls - m.calls_at_last_wake
        potential = max(
            t.reference_score_s - max(t.explorer.best_score, 0.0), 0.0
        )
        # gain-rate estimate, damped by how much we already invested here
        return (potential * (1.0 + calls_since)) / (
            1.0 + t.accounts.regenerations
        )

    def _candidates(self) -> list[tuple[float, ManagedTuner]]:
        """Wakeable tuners with their priorities, best first
        (registration order ties).

        ``sorted`` is stable, so equal priorities (e.g. several +inf
        bootstrap kernels) keep registration order.
        """
        prioritized = [(self._priority(m), m) for m in self._managed]
        eligible = [(p, i, m) for i, (p, m) in enumerate(prioritized)
                    if p > float("-inf")]
        eligible.sort(key=lambda t: (-t[0], t[1]))
        return [(p, m) for p, _, m in eligible]

    def pump(self) -> bool:
        """One scheduling slot: hand the farm a prioritized batch.

        Returns True when some wake swapped in a faster variant. Up to
        ``generator.workers`` kernels get a productive wake per pump
        (one without a farm) — the farm has that many compile slots, so
        a single pump can keep every worker fed; each woken kernel's
        request is submitted at its scheduling priority and its next
        proposals are prefetched. A kernel frozen by its own
        latency-headroom gate — or merely waiting for its background
        compile — passes the slot to the next candidate (an over-SLO
        prefill must not starve a fast decode step forever); a
        shared-budget denial instead ends the whole pump, so accruing
        budget stays earmarked for the most valuable kernels rather
        than leaking to cheaper, lower-value ones. The one exception:
        when the budget still has headroom at the kernel's own cost
        EWMA, the denial was its next *candidate's* predicted cost
        (cost-model compilettes gate on it) — an individually
        unaffordable variant passes the slot instead of freezing every
        other kernel behind it.

        With async generation a productive wake is either a *request*
        (next variant submitted to the farm) or a *harvest* (compiled
        candidate evaluated, maybe swapped); one batch of queued jobs —
        up to ``workers`` of them, highest priority first — completes at
        the top of the pump, so in the deterministic "manual" mode a
        variant requested at pump *k* is harvestable at pump *k+1* —
        never sooner (max-overlap semantics: the batch's wall time hides
        inside the serving interval, its full cost is billed).
        """
        batch = 1
        if self.generator is not None:
            self.generator.run_pending()
            batch = self.generator.workers
        self._maybe_sync()
        self.sweep()
        with self._lock:
            candidates = self._candidates()
        progressed = 0
        any_swapped = False
        for prio, m in candidates:
            t = m.tuner
            # progress = a measurement reported (sync cycle, async
            # harvest, or a failed generation logged as a hole) or an
            # async generation requested
            before = t.explorer.state.n_reported + t.accounts.gen_requests
            t.submit_priority = prio
            any_swapped |= t.wake()
            if t.explorer.state.n_reported + t.accounts.gen_requests != before:
                m.calls_at_last_wake = t.accounts.kernel_calls
                self._flush_best(m)
                self._prefetch(m, prio)
                progressed += 1
                if progressed >= batch:
                    break
                continue
            if t.generation_in_flight:
                # waiting on the compile farm: the slot moves on, the
                # hot path keeps running the current active_fn un-stalled
                continue
            # the slot did nothing here: leave this kernel's hotness
            # signal intact — resetting it would starve exactly the
            # kernel we judged most valuable
            est = t._cost_ema or 0.0
            if not self.policy.headroom_allows(t.accounts, est):
                continue       # per-kernel headroom freeze: next
            candidate = t._candidate_cost_estimate()
            if candidate > est and self._shared_budget_gate(
                    t.accounts, self.clock(), est):
                # budget has headroom at this kernel's own cost EWMA: the
                # denial was its next CANDIDATE's predicted cost — a
                # per-kernel condition, so pass the slot rather than
                # freezing the whole fleet behind one expensive variant
                continue
            break              # shared-budget denial: the pump ends
        return any_swapped

    # ----------------------------------------------------------- prefetch
    def _prefetch(self, m: ManagedTuner, priority: float = 0.0) -> None:
        """Speculatively compile the next 1–2 proposals of ``m``.

        ``SearchStrategy.peek`` exposes the upcoming candidates without
        consuming them; submitting them (speculative) fills the
        generation cache while the current measurement — or plain
        serving — runs, so the tuner's own later request is a hit. The
        compile time is charged to the requesting tuner at completion
        whether or not the variant is ever proposed: prefetch spends real
        compute and the shared budget must see it. Submissions carry the
        kernel's scheduling priority (speculation sorts after requests at
        equal priority in the farm's queue) and stop at the farm's
        per-kernel in-flight cap — rejected prefetches simply retry on a
        later slot.
        """
        if self.generator is None or self.prefetch <= 0:
            return
        t = m.tuner
        if t.explorer.finished or m.state is not TunerState.ACTIVE:
            return
        now = self.clock()
        est = t._cost_ema or 0.0
        for point in t.explorer.peek(self.prefetch):
            # consecutive productive wakes peek the same still-unproposed
            # points: skip ones already resident instead of materializing
            # throwaway hit wrappers (which would also inflate hit stats)
            if (t.compilette.cache is not None
                    and t.compilette.cache_key(point, t.specialization)
                    in t.compilette.cache):
                continue
            if not self._shared_budget_gate(t.accounts, now, est):
                return
            ticket = self.generator.submit(
                t.compilette, point, t.specialization,
                speculative=True, charge_cb=self._speculative_charge(m),
                priority=priority)
            if ticket is None:
                return   # per-kernel cap: this kernel's share is full

    def _speculative_charge(self, m: ManagedTuner):
        """Charge callback billing a prefetch compile to its requester.

        In "thread" mode this runs on the compile worker, so the += on
        the shared accounts must be serialized against the tuning
        thread's own charges (``tuner._lock``) — a lost update here would
        leak budget past ``max_overhead_frac``.
        """

        def charge(ticket: GenerationTicket, seconds: float) -> None:
            # state check and write happen under the coordinator lock —
            # sweep() folds accounts into the tombstone under the same
            # lock, so the charge can never land on an already-folded,
            # discarded accounts object and vanish from the aggregate.
            # Lock order (coordinator -> tuner) matches sweep's
            # abandon_pending path; wake never takes the coordinator
            # lock, so there is no cycle.
            with self._lock:
                if m.state is TunerState.RETIRED:
                    self._retired_accounts.gen_spent_s += seconds
                    self._retired_accounts.tuning_spent_s += seconds
                else:
                    with m.tuner._lock:
                        m.tuner.accounts.gen_spent_s += seconds
                        m.tuner.accounts.tuning_spent_s += seconds

        return charge

    # ----------------------------------------------------------- lifecycle
    def _flush_best(self, m: ManagedTuner) -> None:
        best = m.tuner.explorer.best_point
        if best is not None:
            self.registry.put(
                m.name, m.specialization,
                m.registry_device or self.device,
                best, m.tuner.explorer.best_score,
                strategy=m.tuner.explorer.name,
                traits=m.device_traits,
            )

    def _fold_into_tombstone(self, m: ManagedTuner) -> None:
        m.tuner._update_gains()
        self._accumulate(self._retired_accounts, m.tuner.accounts)

    # ---------------------------------------------------------------- fleet
    def _flush_evals(self, m: ManagedTuner) -> None:
        """Publish new local measurements to the registry's fleet ledger."""
        history = m.tuner.explorer.history
        for point, score_s in history[m.evals_flushed:]:
            if score_s == float("inf"):
                continue   # holes/failures travel via the quarantine table
            self.registry.record_evaluation(
                m.name, m.specialization,
                m.registry_device or self.device, point, score_s)
        m.evals_flushed = len(history)

    def _adopt_fleet_state(self, m: ManagedTuner) -> None:
        """Fold the merged registry back into one live tuner.

        Quarantine first (a peer's verdict beats everything: abort a
        matching canary, demote a matching incumbent), then peer
        evaluations (mark seen — never re-compiled here), then the fleet
        best — injected as a CANDIDATE so it still passes this replica's
        gate/canary before ever serving traffic.
        """
        t = m.tuner
        space = t.compilette.space
        dev = m.registry_device or self.device
        for p in self.registry.quarantined_points(m.name, m.specialization,
                                                  dev):
            if space.contains(p):
                t.adopt_quarantine(p, "fleet quarantine")
        for p in self.registry.evaluated_points(m.name, m.specialization,
                                                dev):
            if space.contains(p):
                t.explorer.mark_seen(p)
        entry = self.registry.best_entry(m.name, m.specialization, dev)
        if entry is not None:
            point, score_s = entry
            if (score_s < t.explorer.best_score
                    and t.explorer.inject_candidate(point)
                    and m.state is TunerState.CONVERGED):
                # new fleet work for an exhausted tuner: wake it back up
                m.state = TunerState.ACTIVE

    def sync_fleet(self) -> bool:
        """One fleet round-trip: publish local state, adopt the merge.

        Local bests and measurement history go into the registry, the
        backend merges that snapshot with every peer's (commutative
        lower-score-wins / quarantine-union join), and the merged state
        is folded back into the registry and every live tuner. Returns
        True when a sync ran.
        """
        if self.registry_backend is None:
            return False
        with self._lock:
            for m in self._managed:
                self._flush_best(m)
                self._flush_evals(m)
        merged = self.registry_backend.sync(self.registry.snapshot())
        self.registry.merge_snapshot(merged)
        self.fleet_syncs += 1
        with self._lock:
            for m in self._managed:
                self._adopt_fleet_state(m)
        return True

    def _maybe_sync(self) -> bool:
        """Sync at the configured cadence (None = every pump)."""
        if self.registry_backend is None:
            return False
        now = self.clock()
        if (self.sync_every_s is not None
                and self._last_sync_s is not None
                and now - self._last_sync_s < self.sync_every_s):
            return False
        self._last_sync_s = now
        return self.sync_fleet()

    def sweep(self) -> list[ManagedTuner]:
        """One lifecycle pass: converge exhausted tuners, evict idle ones.

        Returns the tuners retired by this pass. Called from every
        ``pump`` and at request end (``serve_loop.generate``); cheap —
        O(n_managed) attribute checks.
        """
        now = self.clock()
        retired: list[ManagedTuner] = []
        with self._lock:
            for m in list(self._managed):
                if (m.state is TunerState.ACTIVE
                        and m.tuner.explorer.finished):
                    m.state = TunerState.CONVERGED
                    self._flush_best(m)
                if m.state is TunerState.CONVERGED:
                    # idempotent: serve code may have re-pinned the
                    # evaluator closure on re-register; drop it again
                    release_evaluator_closure(m.tuner)
                if self.lifecycle.should_evict(m.last_used_s, now):
                    m.state = TunerState.RETIRED
                    self._flush_best(m)
                    release_evaluator_closure(m.tuner)
                    # an unharvested compile must still be billed: done
                    # tickets charge the accounts now (folded below),
                    # in-flight ones bill the tombstone at completion
                    m.tuner.abandon_pending(self._speculative_charge(m))
                    self._fold_into_tombstone(m)
                    self._managed.remove(m)
                    self._by_key.pop(
                        (m.name, _canon_spec(m.specialization)), None)
                    self._n_retired += 1
                    retired.append(m)
        return retired

    def maybe_pump(self) -> bool:
        """Cooperative pacing: call once per application step/iteration."""
        self._app_calls += 1
        if self._thread is not None:
            return False
        if self._app_calls % self.pump_every:
            return False
        return self.pump()

    @property
    def finished(self) -> bool:
        """Every CURRENTLY managed tuner has exhausted its space.

        Not a terminal state: serve traffic can register new tuners (or
        re-register evicted ones) at any time, which is why the
        coordinator thread keeps pumping regardless.
        """
        return all(m.tuner.explorer.finished for m in self._managed)

    # ------------------------------------------------------------ threaded
    def start_thread(self, wake_period_s: float = 0.002) -> None:
        """Single per-process tuning thread (replaces one thread/kernel)."""
        if self._thread is not None:
            return

        def _loop() -> None:
            # Runs until stop_thread(): unlike a single autotuner's space,
            # the coordinator's tuner set grows back — serve traffic
            # re-registers after eviction, so "all finished" (or empty
            # after a lull) is not a terminal state. Idle pumps are cheap
            # (one lifecycle sweep + a no-op pick).
            while not self._stop.is_set():
                self.pump()
                self._stop.wait(wake_period_s)

        self._thread = threading.Thread(
            target=_loop, daemon=True, name="tuning-coordinator"
        )
        self._thread.start()

    def stop_thread(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        self._stop = threading.Event()

    # --------------------------------------------------------- persistence
    def save_registry(self, path: str | None = None) -> None:
        path = path or self.registry_path
        if path is None:
            return
        # flush current bests before writing (retired tuners were flushed
        # at retirement)
        for m in self._managed:
            self._flush_best(m)
        self.registry.save(path)

    def close(self) -> None:
        self.stop_thread()
        if self.generator is not None:
            self.generator.shutdown()
        # final fleet publish: bests/quarantines found since the last
        # cadenced sync must not die with this replica
        self.sync_fleet()
        self.save_registry()

    # ------------------------------------------------------------- reports
    def stats(self) -> dict[str, Any]:
        agg = self._aggregate_accounts()
        elapsed = self.clock() - self.app_start_s
        return {
            "device": self.device,
            "n_kernels": len(self._managed),
            "regenerations": agg.regenerations,
            "swaps": agg.swaps,
            "tuning_spent_s": agg.tuning_spent_s,
            # component split: tuning_spent_s ≈ gen + eval; the paper's
            # per-component overhead-fraction claim is checkable here,
            # and gen_stall_s isolates what the hot path actually waited
            # for (0 when every compile was overlapped or cache-hit)
            "gen_spent_s": agg.gen_spent_s,
            "gen_stall_s": agg.gen_stall_s,
            "eval_spent_s": agg.eval_spent_s,
            "gen_requests": agg.gen_requests,
            "init_spent_s": agg.init_spent_s,
            "busy_s": agg.busy_s,
            "gained_s": agg.gained_s,
            "overhead_frac": (
                agg.tuning_spent_s / elapsed if elapsed > 0 else 0.0
            ),
            # trusted-swaps rollup: per-kernel entries + retired_accounts
            # below reconcile exactly with these aggregates
            "gate_mode": self.gate_mode,
            "gate_spent_s": agg.gate_spent_s,
            "gate_checks": agg.gate_checks,
            "gate_failures": agg.gate_failures,
            "canary_calls": agg.canary_calls,
            "canary_promotions": agg.canary_promotions,
            "rollbacks": agg.rollbacks,
            "quarantined": agg.quarantined,
            "budget_s": self.policy.budget_s(agg, self.clock()),
            "budget_spent_s": self.policy.spent_s(agg),
            "lifecycle": {
                "active": sum(1 for m in self._managed
                              if m.state is TunerState.ACTIVE),
                "converged": sum(1 for m in self._managed
                                 if m.state is TunerState.CONVERGED),
                "retired": self._n_retired,
            },
            # tombstone breakdown: per-kernel entries below only cover
            # CURRENTLY managed tuners, so per-kernel sums + these retired
            # totals reconcile exactly with the aggregate fields above
            "retired_accounts": {
                f: getattr(self._retired_accounts, f)
                for f in ("tuning_spent_s", "gen_spent_s", "gen_stall_s",
                          "eval_spent_s", "gained_s", "regenerations",
                          "swaps", "gate_spent_s", "gate_checks",
                          "gate_failures", "canary_calls",
                          "canary_promotions", "rollbacks", "quarantined")
            },
            "generation_cache": self.generation_cache.stats(),
            "generation": (self.generator.stats()
                           if self.generator is not None
                           else {"mode": "sync"}),
            "fleet": {
                "replica_id": self.replica_id,
                "replica_count": self.replica_count,
                "backend": (type(self.registry_backend).__name__
                            if self.registry_backend is not None else None),
                "syncs": self.fleet_syncs,
            },
            **self._transfer_stats(),
            "kernels": self._kernel_stats(),
        }

    @staticmethod
    def _regens_to_best(tuner: OnlineAutotuner) -> int | None:
        """1-based history index where the final best score first landed."""
        ex = tuner.explorer
        if ex.best_point is None:
            return None
        for i, (_, score) in enumerate(ex.history, 1):
            if score <= ex.best_score:
                return i
        return None

    def _transfer_stats(self) -> dict[str, Any]:
        """Transfer-plane counters: hits, adoptions, time-to-best.

        ``transfer_hits`` counts seeds injected; ``transfer_adopted``
        counts live tuners whose CURRENT best is one of their own
        transfer seeds (it survived gate/canary and won); and
        ``seeded_regens_to_best`` is the mean regenerations a
        transfer-seeded tuner needed to reach its best — the fig-5-at-
        fleet-scale claim is that this stays ~1 while cold search pays
        the whole enumeration.
        """
        adopted = 0
        regens: list[int] = []
        for m in self._managed:
            if not m.transfer_seed_keys:
                continue
            space = m.tuner.compilette.space
            best = m.tuner.explorer.best_point
            if best is not None and space.key(best) in m.transfer_seed_keys:
                adopted += 1
            r = self._regens_to_best(m.tuner)
            if r is not None:
                regens.append(r)
        return {
            "transfer_enabled": self.transfer,
            "transfer_hits": self.transfer_hits,
            "transfer_adopted": adopted,
            "seeded_regens_to_best": (
                sum(regens) / len(regens) if regens else None),
        }

    def _kernel_stats(self) -> dict[str, dict[str, Any]]:
        out: dict[str, dict[str, Any]] = {}
        for m in self._managed:
            key = m.name
            if key in out:   # same kernel, different specialization
                key = f"{m.name}@{_canon_spec(m.specialization)}"
            out[key] = m.stats()
        return out
