"""Serving runtime: batched prefill + greedy decode with KV/state cache.

Online auto-tuning (paper technique, serving workload) is configured by
the embedded :class:`~repro.api.TuningConfig` (``ServeConfig.tuning``)
and owned by a :class:`~repro.api.TuningSession` — the one front door to
the coordinator machinery. The serving regime it runs under:

  * the regeneration budget accrues from **busy time** (kernel-call time
    actually observed), not lifetime wall-clock, so a long-idle server
    cannot burst accrued budget onto one request; the register()-time
    reference measurement is charged to the same budget;
  * sequence lengths are **bucketed to powers of two** (nearest in log
    space), so varied prompt shapes share tuners instead of accumulating
    one tuner (plus pinned evaluation closures) per exact shape;
  * exhausted tuners converge (closures released) and idle tuners are
    evicted by the session lifecycle;
  * the search strategy is pluggable (``TuningConfig.strategy``: any
    name registered in :mod:`repro.core.explorer`);
  * **candidate compilation is off the request path**: variants are
    built by the session's background pipeline (and memoized in its
    process-wide generation cache, so buckets re-registered after
    eviction or a restart warm-start never recompile) while the live
    step-programs keep serving — the paper's double-buffered code
    generation, serving-grade;
  * **hierarchical registration** (``kernel_tuning``): beside the whole
    step-programs, ``session.attach_kernels`` registers the model's
    constituent Pallas kernels (matmul, attention, rmsnorm, and the
    decode path's flash-decoding ``decode_attention`` keyed per
    cache-length bucket) as independent compilettes — each with its own
    tuning space, search strategy, registry warm-start key and
    generation-cache lines, all drawing slots from the same shared
    budget. ``"program"`` is the pre-PR-4 behaviour, ``"kernel"`` tunes
    only the kernels (step-programs adopt the kernels' best block sizes
    at trace time), ``"both"`` runs the two levels together (program
    points own the step-level knobs).

Pass a long-lived session (one per serving process) so tuning state,
budget and warm-started best points persist across requests; within a
single ``generate`` call tuning already begins between decode steps.
``make_serve_coordinator`` and the bare ``coordinator=`` argument remain
as deprecated shims over the session.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.api import (
    KERNEL_TUNING_MODES,
    TuningConfig,
    TuningSession,
    apply_tuning_kwargs,
    install_tuning_aliases,
    serve_tuning_defaults,
)
from repro.configs.base import ModelConfig
from repro.core import (
    Compilette,
    Evaluator,
    Param,
    clamped_options,
    product_space,
)
from repro.models.model import build_model

__all__ = [
    "KERNEL_TUNING_MODES",
    "ServeConfig",
    "generate",
    "make_serve_coordinator",
    "serve_tuning_defaults",   # re-export: the regime base lives in api
]

# legacy ServeConfig field → TuningConfig field
_TUNING_ALIASES = {
    "autotune": "enabled",
    "tune_max_overhead": "max_overhead",
    "tune_invest": "invest",
    "tune_strategy": "strategy",
    "tune_slo_s": "slo_s",
    "tune_slo_quantile": "slo_quantile",
    "seq_buckets": "seq_buckets",
    "idle_evict_s": "idle_evict_s",
    "registry_path": "registry_path",
    "pump_every": "pump_every",
    "async_generation": "async_generation",
    "prefetch": "prefetch",
    "compile_workers": "compile_workers",
    "compile_backend": "compile_backend",
    "kernel_tuning": "kernel_tuning",
    "kernel_strategies": "strategies",
}


class ServeConfig:
    """Serving knobs; tuning knobs live in the embedded ``tuning`` config.

    The legacy flat fields (``autotune``, ``tune_strategy``,
    ``kernel_strategies``, …) remain accepted as constructor keywords
    and readable/writable properties, aliasing into ``self.tuning`` —
    pre-PR-5 call sites keep working unchanged.
    """

    def __init__(
        self,
        max_new_tokens: int = 32,
        greedy: bool = True,
        temperature: float = 1.0,
        seed: int = 0,
        tuning: TuningConfig | None = None,
        **legacy: Any,
    ) -> None:
        self.max_new_tokens = max_new_tokens
        self.greedy = greedy
        self.temperature = temperature
        self.seed = seed
        self.tuning = tuning if tuning is not None else \
            serve_tuning_defaults()
        apply_tuning_kwargs(self.tuning, _TUNING_ALIASES, legacy,
                            "ServeConfig")

    def __repr__(self) -> str:  # cache_token-stable (identity-free)
        return (f"ServeConfig(max_new_tokens={self.max_new_tokens}, "
                f"greedy={self.greedy}, temperature={self.temperature}, "
                f"seed={self.seed}, tuning={self.tuning})")


install_tuning_aliases(ServeConfig, _TUNING_ALIASES)


def _prefill_compilette(model_cfg: ModelConfig, seq: int) -> Compilette:
    """Points are prefill step-programs: attention chunking variants.

    ``seq`` is the (bucketed) sequence extent bounding the chunk options.
    """
    space = product_space([
        Param("attn_q_chunk", clamped_options((32, 64, 128, 256), seq),
              phase=1, switch_rank=0),
        Param("attn_k_chunk", clamped_options((32, 64, 128, 256), seq),
              phase=1, switch_rank=1),
    ])

    def gen(point, **spec):
        cfg2 = dataclasses.replace(
            model_cfg,
            attn_q_chunk=point["attn_q_chunk"],
            attn_k_chunk=point["attn_k_chunk"],
        )
        return jax.jit(build_model(cfg2).prefill)

    # cache_token: compilettes named "serve_prefill" exist per model
    # config; without the token the process-wide GenerationCache could
    # hand one model's compiled step-program to another with the same
    # shape specialization
    return Compilette("serve_prefill", space, gen,
                      cache_token=repr(model_cfg))


def _decode_compilette(model_cfg: ModelConfig, max_len: int) -> Compilette:
    """Points are decode step-programs: flash-decoding KV-chunk variants."""
    space = product_space([
        Param("decode_k_chunk",
              clamped_options((128, 256, 512, 1024, 4096), max_len),
              phase=1),
    ])

    def gen(point, **spec):
        cfg2 = dataclasses.replace(
            model_cfg, decode_k_chunk=point["decode_k_chunk"])
        return jax.jit(build_model(cfg2).decode_step)

    return Compilette("serve_decode", space, gen,
                      cache_token=repr(model_cfg))


def make_serve_coordinator(serve: ServeConfig, *, clock=None):
    """Deprecated: build the serving session's coordinator directly.

    Thin shim over :class:`repro.api.TuningSession` — the session API is
    the one front door; this remains so pre-PR-5 call sites (and their
    tests) keep working. Returns the coordinator of a fresh session; the
    session is recoverable via ``TuningSession.adopt``.
    """
    warnings.warn(
        "make_serve_coordinator is deprecated: construct a "
        "repro.TuningSession(serve.tuning) and pass session=... to "
        "generate()", DeprecationWarning, stacklevel=2)
    return TuningSession(serve.tuning, clock=clock).coordinator


def generate(
    model_cfg: ModelConfig,
    batch: dict[str, Any],
    serve: ServeConfig | None = None,
    coordinator: Any | None = None,
    session: TuningSession | None = None,
) -> dict[str, Any]:
    """Prefill the prompt batch, then decode ``max_new_tokens`` greedily.

    Tuning state lives in ``session`` (one per serving process). The
    legacy ``coordinator=`` argument is adopted into its session; with
    neither, an ephemeral session is built from ``serve.tuning`` and
    closed when the request finishes.
    """
    serve = serve or ServeConfig()
    tcfg = serve.tuning
    if tcfg.kernel_tuning not in KERNEL_TUNING_MODES:
        raise ValueError(
            f"kernel_tuning must be one of {KERNEL_TUNING_MODES}, "
            f"got {tcfg.kernel_tuning!r}")
    tune_program = tcfg.tune_program
    tune_kernels = tcfg.tune_kernels
    tuning = tune_program or tune_kernels
    own_session = False
    if tuning and session is None:
        if coordinator is not None:
            session = TuningSession.adopt(coordinator, tcfg)
        else:
            session = TuningSession(tcfg)
            own_session = True
    model = build_model(model_cfg)
    from repro.models.params import init_tree
    params = batch.pop("params", None)
    if params is None:
        params = init_tree(model.param_defs(), jax.random.PRNGKey(serve.seed),
                           model_cfg.param_dtype)

    B, T = batch["tokens"].shape
    max_len = T + serve.max_new_tokens
    if model_cfg.family == "vlm":
        max_len += model_cfg.vision_patches

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    # ---- online tuning: step-programs + constituent kernels -------------
    tune_init_s = 0.0
    decode_state: dict[str, Any] = {}
    if tune_kernels:
        # Hierarchical registration, kernel level: the model's
        # constituent Pallas kernels become independent session-managed
        # compilettes (own space/strategy/registry key), drawing
        # regeneration slots from the same shared budget as the
        # step-programs. Untunable shapes (every point a hole at a
        # reduced size) are skipped, not fatal.
        t_init = time.perf_counter()
        session.attach_kernels(model_cfg, batch=B, seq=T, max_len=max_len)
        tune_init_s += time.perf_counter() - t_init
    if tune_program:
        t_init = time.perf_counter()
        # The compilette's chunk options are bounded by the BUCKETED
        # extent, matching the bucketed specialization key the
        # session registers under — so seq 120 and 150 build the
        # identical 128-bucket space and share one tuner.
        seq_b = session.coordinator.lifecycle.bucket_length(T)
        prefill_ev = Evaluator(
            mode="real", real_runs=1, warmup=1,
            make_args=lambda: (params, batch))
        prefill = session.register(
            "serve_prefill", _prefill_compilette(model_cfg, seq_b),
            prefill_ev,
            specialization={"seq": T, "batch": B},
            reference_fn=prefill,
        )
        # register() is idempotent across requests: point the (possibly
        # pre-existing) evaluator at THIS request's inputs so measurements
        # stay representative of live traffic.
        prefill.tuner.evaluator.make_args = prefill_ev.make_args
        tune_init_s += time.perf_counter() - t_init

    # The session scope stays active for the whole request: jitted
    # step-programs traced in here adopt tuned kernel block sizes, and
    # any eager kernel call routes through its managed handle.
    scope_ctx = session.scope() if session is not None \
        else contextlib.nullcontext()
    try:
        with scope_ctx:
            return _generate_inner(
                model_cfg, model, params, batch, serve, session,
                prefill, decode, B, T, max_len, tuning, tune_program,
                tune_init_s, decode_state)
    finally:
        if own_session:
            session.close()


def _generate_inner(
    model_cfg, model, params, batch, serve, session,
    prefill, decode, B, T, max_len, tuning, tune_program,
    tune_init_s, decode_state,
) -> dict[str, Any]:
    # Busy-time credit for unmanaged step-programs: with kernel-only
    # tuning the prefill/decode calls are real traffic a busy-time
    # budget must accrue from, but no ManagedTuner counts them (a
    # managed step reports its own calls — never double-credit).
    credit_busy = tuning and not tune_program

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    if credit_busy:
        jax.block_until_ready(logits)
        session.observe_busy(time.perf_counter() - t0)
    # widen KV caches to max_len where the family uses positional caches
    full = model.init_cache(B, max_len)
    widened = []
    for got, want in zip(cache, full):
        if got.shape == want.shape:
            widened.append(got)
        else:
            pads = [(0, w - g) for g, w in zip(got.shape, want.shape)]
            widened.append(jnp.pad(got, pads))
    cache = tuple(widened)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tokens]
    pos0 = T if model_cfg.family != "vlm" else T + model_cfg.vision_patches

    if tune_program:
        # The decode evaluator replays the *current* decoding state; its
        # outputs are discarded, so measurement is side-effect-free.
        t_init = time.perf_counter()
        decode_state.update(cache=cache, tokens=tokens, pos=jnp.int32(pos0))
        max_len_b = session.coordinator.lifecycle.bucket_length(max_len)
        decode_ev = Evaluator(
            mode="real", real_runs=1, warmup=1,
            make_args=lambda: (params, decode_state["cache"],
                               decode_state["tokens"], decode_state["pos"]))
        decode = session.register(
            "serve_decode", _decode_compilette(model_cfg, max_len_b),
            decode_ev,
            specialization={"max_len": max_len, "batch": B},
            reference_fn=decode,
        )
        decode.tuner.evaluator.make_args = decode_ev.make_args
        tune_init_s += time.perf_counter() - t_init

    t1 = time.perf_counter()
    for i in range(serve.max_new_tokens - 1):
        t_step = time.perf_counter()
        logits, cache = decode(params, cache, tokens, jnp.int32(pos0 + i))
        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tokens)
        if tuning:
            if credit_busy:
                # sync before crediting: jax dispatch is asynchronous, so
                # without it the credited interval would be the enqueue
                # time (µs) while the device executes inside the final
                # block_until_ready — and a busy-time budget would starve
                # exactly the kernel tuning this credit exists to fund
                jax.block_until_ready(tokens)
                session.observe_busy(time.perf_counter() - t_step)
            if tune_program:
                decode_state.update(
                    cache=cache, tokens=tokens, pos=jnp.int32(pos0 + i + 1))
            session.maybe_pump()
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t1

    generated = jnp.concatenate(out_tokens, axis=1)
    n_new = generated.shape[1]
    out = {
        "tokens": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens_per_s": B * n_new / t_decode if t_decode > 0 else 0.0,
    }
    if tuning:
        session.save()
        # Lifecycle pass at request end: converged tuners release the
        # evaluator closures pinning this request's params/batch/cache,
        # and tuners idle past the eviction horizon are unregistered.
        session.sweep()
        out["tune_init_s"] = tune_init_s
        out["kernel_tuning"] = serve.tuning.kernel_tuning
        out["autotune"] = session.stats()
    return out
