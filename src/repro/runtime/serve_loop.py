"""Serving runtime: batched prefill + greedy decode with KV/state cache.

Online auto-tuning (paper technique, serving workload): the prefill and
decode step-programs are tunable compilettes — attention chunking for
prefill, flash-decoding KV-chunk for decode — managed by the process-wide
:class:`TuningCoordinator` under a strict serving overhead cap. Pass a
long-lived coordinator (one per serving process) so tuning state, budget
and warm-started best points persist across requests; within a single
``generate`` call tuning already begins between decode steps.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import Compilette, Evaluator, Param, RegenerationPolicy, product_space
from repro.models.model import build_model
from repro.runtime.coordinator import TuningCoordinator


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # --- online auto-tuning (off by default: zero-overhead serving) ------
    autotune: bool = False
    tune_max_overhead: float = 0.05   # strict serving cap: ≤5 % of wall
    tune_invest: float = 0.10
    registry_path: str | None = None  # warm-start across server restarts
    pump_every: int = 4               # decode steps between tuning slots


def _clamped(options: tuple[int, ...], bound: int) -> tuple[int, ...]:
    """Deduplicate chunk options past ``bound``: values larger than the
    sequence all compile to the same program, and re-measuring duplicates
    would waste the shared regeneration budget."""
    return tuple(sorted({min(v, bound) for v in options}))


def _prefill_compilette(model_cfg: ModelConfig, seq: int) -> Compilette:
    """Points are prefill step-programs: attention chunking variants."""
    space = product_space([
        Param("attn_q_chunk", _clamped((32, 64, 128, 256), seq),
              phase=1, switch_rank=0),
        Param("attn_k_chunk", _clamped((32, 64, 128, 256), seq),
              phase=1, switch_rank=1),
    ])

    def gen(point, **spec):
        cfg2 = dataclasses.replace(
            model_cfg,
            attn_q_chunk=point["attn_q_chunk"],
            attn_k_chunk=point["attn_k_chunk"],
        )
        return jax.jit(build_model(cfg2).prefill)

    return Compilette("serve_prefill", space, gen)


def _decode_compilette(model_cfg: ModelConfig, max_len: int) -> Compilette:
    """Points are decode step-programs: flash-decoding KV-chunk variants."""
    space = product_space([
        Param("decode_k_chunk",
              _clamped((128, 256, 512, 1024, 4096), max_len), phase=1),
    ])

    def gen(point, **spec):
        cfg2 = dataclasses.replace(
            model_cfg, decode_k_chunk=point["decode_k_chunk"])
        return jax.jit(build_model(cfg2).decode_step)

    return Compilette("serve_decode", space, gen)


def make_serve_coordinator(
    serve: ServeConfig, *, clock=None
) -> TuningCoordinator:
    """One coordinator per serving process (shared across requests)."""
    return TuningCoordinator(
        policy=RegenerationPolicy(
            max_overhead_frac=serve.tune_max_overhead,
            invest_frac=serve.tune_invest,
        ),
        registry_path=serve.registry_path,
        pump_every=serve.pump_every,
        clock=clock,
    )


def generate(
    model_cfg: ModelConfig,
    batch: dict[str, Any],
    serve: ServeConfig | None = None,
    coordinator: TuningCoordinator | None = None,
) -> dict[str, Any]:
    """Prefill the prompt batch, then decode ``max_new_tokens`` greedily."""
    serve = serve or ServeConfig()
    model = build_model(model_cfg)
    from repro.models.params import init_tree
    params = batch.pop("params", None)
    if params is None:
        params = init_tree(model.param_defs(), jax.random.PRNGKey(serve.seed),
                           model_cfg.param_dtype)

    B, T = batch["tokens"].shape
    max_len = T + serve.max_new_tokens
    if model_cfg.family == "vlm":
        max_len += model_cfg.vision_patches

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    # ---- online tuning of the two step-programs -------------------------
    tune_init_s = 0.0
    decode_state: dict[str, Any] = {}
    if serve.autotune:
        t_init = time.perf_counter()
        if coordinator is None:
            coordinator = make_serve_coordinator(serve)
        prefill_ev = Evaluator(
            mode="real", real_runs=1, warmup=1,
            make_args=lambda: (params, batch))
        prefill = coordinator.register(
            "serve_prefill", _prefill_compilette(model_cfg, T), prefill_ev,
            specialization={"seq": T, "batch": B},
            reference_fn=prefill,
        )
        # register() is idempotent across requests: point the (possibly
        # pre-existing) evaluator at THIS request's inputs so measurements
        # stay representative of live traffic.
        prefill.tuner.evaluator.make_args = prefill_ev.make_args
        tune_init_s = time.perf_counter() - t_init

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    # widen KV caches to max_len where the family uses positional caches
    full = model.init_cache(B, max_len)
    widened = []
    for got, want in zip(cache, full):
        if got.shape == want.shape:
            widened.append(got)
        else:
            pads = [(0, w - g) for g, w in zip(got.shape, want.shape)]
            widened.append(jnp.pad(got, pads))
    cache = tuple(widened)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tokens]
    pos0 = T if model_cfg.family != "vlm" else T + model_cfg.vision_patches

    if serve.autotune:
        # The decode evaluator replays the *current* decoding state; its
        # outputs are discarded, so measurement is side-effect-free.
        t_init = time.perf_counter()
        decode_state.update(cache=cache, tokens=tokens, pos=jnp.int32(pos0))
        decode_ev = Evaluator(
            mode="real", real_runs=1, warmup=1,
            make_args=lambda: (params, decode_state["cache"],
                               decode_state["tokens"], decode_state["pos"]))
        decode = coordinator.register(
            "serve_decode", _decode_compilette(model_cfg, max_len), decode_ev,
            specialization={"max_len": max_len, "batch": B},
            reference_fn=decode,
        )
        decode.tuner.evaluator.make_args = decode_ev.make_args
        tune_init_s += time.perf_counter() - t_init

    t1 = time.perf_counter()
    for i in range(serve.max_new_tokens - 1):
        logits, cache = decode(params, cache, tokens, jnp.int32(pos0 + i))
        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tokens)
        if serve.autotune:
            decode_state.update(
                cache=cache, tokens=tokens, pos=jnp.int32(pos0 + i + 1))
            coordinator.maybe_pump()
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t1

    generated = jnp.concatenate(out_tokens, axis=1)
    n_new = generated.shape[1]
    out = {
        "tokens": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens_per_s": B * n_new / t_decode if t_decode > 0 else 0.0,
    }
    if serve.autotune:
        coordinator.save_registry()
        # Evaluator closures pin this request's params/batch/cache so
        # between-request pumps can still measure variants; once a tuner
        # has exhausted its space nothing will evaluate again — release
        # the arrays instead of holding them for the coordinator's life.
        for managed in (prefill, decode):
            if managed.tuner.explorer.finished:
                managed.tuner.evaluator.make_args = None
        out["tune_init_s"] = tune_init_s
        out["autotune"] = coordinator.stats()
    return out
