"""Serving runtime: batched prefill + greedy decode with KV/state cache.

Online auto-tuning (paper technique, serving workload): the prefill and
decode step-programs are tunable compilettes — attention chunking for
prefill, flash-decoding KV-chunk for decode — managed by the process-wide
:class:`TuningCoordinator` under a serving-grade regime:

  * the regeneration budget accrues from **busy time** (kernel-call time
    actually observed), not lifetime wall-clock, so a long-idle server
    cannot burst accrued budget onto one request; the register()-time
    reference measurement is charged to the same budget;
  * sequence lengths are **bucketed to powers of two** (nearest in log
    space), so varied prompt shapes share tuners instead of accumulating
    one tuner (plus pinned evaluation closures) per exact shape;
  * exhausted tuners converge (closures released) and idle tuners are
    evicted by the coordinator's :class:`TunerLifecycle`;
  * the search strategy is pluggable (``ServeConfig.strategy``: any name
    registered in :mod:`repro.core.explorer`);
  * **candidate compilation is off the request path**: variants are
    built by the coordinator's background :class:`AsyncGenerator` (and
    memoized in its process-wide :class:`GenerationCache`, so buckets
    re-registered after eviction or a restart warm-start never
    recompile) while the live step-programs keep serving — the paper's
    double-buffered code generation, serving-grade;
  * **hierarchical registration** (``kernel_tuning``): beside the whole
    step-programs, the model's constituent Pallas kernels (matmul,
    attention, rmsnorm) register as independent compilettes through the
    :class:`~repro.runtime.kernel_plane.KernelTuningPlane` — each with
    its own tuning space, search strategy (``kernel_strategies``),
    registry warm-start key and generation-cache lines, all drawing
    slots from the same shared budget. ``"program"`` is the pre-PR-4
    behaviour, ``"kernel"`` tunes only the kernels (step-programs adopt
    the kernels' best block sizes at trace time), ``"both"`` runs the
    two levels together (program points own the step-level knobs).

Pass a long-lived coordinator (one per serving process) so tuning state,
budget and warm-started best points persist across requests; within a
single ``generate`` call tuning already begins between decode steps.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import (
    Compilette,
    Evaluator,
    LatencyHeadroomGate,
    Param,
    RegenerationPolicy,
    clamped_options,
    product_space,
)
from repro.models.model import build_model, model_kernel_specs
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.kernel_plane import KernelTuningPlane, use_kernel_plane
from repro.runtime.lifecycle import TunerLifecycle

KERNEL_TUNING_MODES = ("off", "program", "kernel", "both")


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # --- online auto-tuning (off by default: zero-overhead serving) ------
    autotune: bool = False
    tune_max_overhead: float = 0.05   # strict serving cap: ≤5 % of BUSY time
    tune_invest: float = 0.10
    tune_strategy: str = "two_phase"  # any repro.core.explorer registry name
    tune_slo_s: float | None = None   # per-step latency SLO (headroom gate)
    tune_slo_quantile: float | None = None  # e.g. 0.99: gate on p99, not mean
    seq_buckets: bool = True          # pow2-bucket seq/max_len tuner keys
    idle_evict_s: float | None = 300.0  # retire tuners idle this long
    registry_path: str | None = None  # warm-start across server restarts
    pump_every: int = 4               # decode steps between tuning slots
    async_generation: bool = True     # compile variants off the hot path
    prefetch: int = 1                 # speculative compiles per slot (0=off)
    kernel_tuning: str = "program"    # off | program | kernel | both
    kernel_strategies: dict[str, str] | None = None  # per-kernel strategy


def _prefill_compilette(model_cfg: ModelConfig, seq: int) -> Compilette:
    """Points are prefill step-programs: attention chunking variants.

    ``seq`` is the (bucketed) sequence extent bounding the chunk options.
    """
    space = product_space([
        Param("attn_q_chunk", clamped_options((32, 64, 128, 256), seq),
              phase=1, switch_rank=0),
        Param("attn_k_chunk", clamped_options((32, 64, 128, 256), seq),
              phase=1, switch_rank=1),
    ])

    def gen(point, **spec):
        cfg2 = dataclasses.replace(
            model_cfg,
            attn_q_chunk=point["attn_q_chunk"],
            attn_k_chunk=point["attn_k_chunk"],
        )
        return jax.jit(build_model(cfg2).prefill)

    # cache_token: compilettes named "serve_prefill" exist per model
    # config; without the token the process-wide GenerationCache could
    # hand one model's compiled step-program to another with the same
    # shape specialization
    return Compilette("serve_prefill", space, gen,
                      cache_token=repr(model_cfg))


def _decode_compilette(model_cfg: ModelConfig, max_len: int) -> Compilette:
    """Points are decode step-programs: flash-decoding KV-chunk variants."""
    space = product_space([
        Param("decode_k_chunk",
              clamped_options((128, 256, 512, 1024, 4096), max_len),
              phase=1),
    ])

    def gen(point, **spec):
        cfg2 = dataclasses.replace(
            model_cfg, decode_k_chunk=point["decode_k_chunk"])
        return jax.jit(build_model(cfg2).decode_step)

    return Compilette("serve_decode", space, gen,
                      cache_token=repr(model_cfg))


def make_serve_coordinator(
    serve: ServeConfig, *, clock=None
) -> TuningCoordinator:
    """One coordinator per serving process (shared across requests)."""
    return TuningCoordinator(
        policy=RegenerationPolicy(
            max_overhead_frac=serve.tune_max_overhead,
            invest_frac=serve.tune_invest,
            # serving-grade budget: accrue from kernel busy time (idle
            # periods earn nothing) and charge reference measurements
            budget_from="busy",
            charge_init=True,
            headroom=(LatencyHeadroomGate(
                slo_s=serve.tune_slo_s,
                slo_quantile=serve.tune_slo_quantile)
                      if serve.tune_slo_s else None),
        ),
        registry_path=serve.registry_path,
        pump_every=serve.pump_every,
        lifecycle=TunerLifecycle(
            seq_buckets=serve.seq_buckets,
            idle_evict_s=serve.idle_evict_s,
        ),
        strategy=serve.tune_strategy,
        clock=clock,
        # double-buffered generation: candidate step-programs compile in
        # the background executor (and land in the process-wide variant
        # cache) while the live prefill/decode functions keep serving
        async_generation=serve.async_generation,
        prefetch=serve.prefetch,
    )


def generate(
    model_cfg: ModelConfig,
    batch: dict[str, Any],
    serve: ServeConfig | None = None,
    coordinator: TuningCoordinator | None = None,
) -> dict[str, Any]:
    """Prefill the prompt batch, then decode ``max_new_tokens`` greedily."""
    serve = serve or ServeConfig()
    if serve.kernel_tuning not in KERNEL_TUNING_MODES:
        raise ValueError(
            f"kernel_tuning must be one of {KERNEL_TUNING_MODES}, "
            f"got {serve.kernel_tuning!r}")
    tune_program = serve.autotune and serve.kernel_tuning in (
        "program", "both")
    tune_kernels = serve.autotune and serve.kernel_tuning in (
        "kernel", "both")
    tuning = tune_program or tune_kernels
    model = build_model(model_cfg)
    from repro.models.params import init_tree
    params = batch.pop("params", None)
    if params is None:
        params = init_tree(model.param_defs(), jax.random.PRNGKey(serve.seed),
                           model_cfg.param_dtype)

    B, T = batch["tokens"].shape
    max_len = T + serve.max_new_tokens
    if model_cfg.family == "vlm":
        max_len += model_cfg.vision_patches

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    # ---- online tuning: step-programs + constituent kernels -------------
    tune_init_s = 0.0
    decode_state: dict[str, Any] = {}
    plane = None
    if tuning and coordinator is None:
        coordinator = make_serve_coordinator(serve)
    if tune_kernels:
        # Hierarchical registration, kernel level: the model's
        # constituent Pallas kernels become independent coordinator-
        # managed compilettes (own space/strategy/registry key), drawing
        # regeneration slots from the same shared budget as the
        # step-programs. Untunable shapes (every point a hole at a
        # reduced size) are skipped, not fatal.
        t_init = time.perf_counter()
        # one plane per coordinator: handles, live args and compilettes
        # persist across requests exactly like the managed tuners do
        plane = KernelTuningPlane.shared(
            coordinator,
            strategies=serve.kernel_strategies,
            # program points own attn_q_chunk/attn_k_chunk in "both"
            # mode; trace-time adoption only when kernels tune alone
            adopt_points=not tune_program,
        )
        seq_b = coordinator.lifecycle.bucket_length(T)
        for name, spec in model_kernel_specs(model_cfg, batch=B, seq=seq_b):
            plane.register_spec(name, spec, require=False)
        tune_init_s += time.perf_counter() - t_init
    if tune_program:
        t_init = time.perf_counter()
        # The compilette's chunk options are bounded by the BUCKETED
        # extent, matching the bucketed specialization key the
        # coordinator registers under — so seq 120 and 150 build the
        # identical 128-bucket space and share one tuner.
        seq_b = coordinator.lifecycle.bucket_length(T)
        prefill_ev = Evaluator(
            mode="real", real_runs=1, warmup=1,
            make_args=lambda: (params, batch))
        prefill = coordinator.register(
            "serve_prefill", _prefill_compilette(model_cfg, seq_b),
            prefill_ev,
            specialization={"seq": T, "batch": B},
            reference_fn=prefill,
        )
        # register() is idempotent across requests: point the (possibly
        # pre-existing) evaluator at THIS request's inputs so measurements
        # stay representative of live traffic.
        prefill.tuner.evaluator.make_args = prefill_ev.make_args
        tune_init_s += time.perf_counter() - t_init

    # The plane stays active for the whole request: jitted step-programs
    # traced in here adopt tuned kernel block sizes, and any eager kernel
    # call routes through its coordinator-managed handle.
    plane_ctx = (use_kernel_plane(plane) if plane is not None
                 else contextlib.nullcontext())
    with plane_ctx:
        return _generate_inner(
            model_cfg, model, params, batch, serve, coordinator,
            prefill, decode, B, T, max_len, tuning, tune_program,
            tune_init_s, decode_state)


def _generate_inner(
    model_cfg, model, params, batch, serve, coordinator,
    prefill, decode, B, T, max_len, tuning, tune_program,
    tune_init_s, decode_state,
) -> dict[str, Any]:
    # Busy-time credit for unmanaged step-programs: with kernel-only
    # tuning the prefill/decode calls are real traffic a busy-time
    # budget must accrue from, but no ManagedTuner counts them (a
    # managed step reports its own calls — never double-credit).
    credit_busy = tuning and not tune_program

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    if credit_busy:
        jax.block_until_ready(logits)
        coordinator.observe_busy(time.perf_counter() - t0)
    # widen KV caches to max_len where the family uses positional caches
    full = model.init_cache(B, max_len)
    widened = []
    for got, want in zip(cache, full):
        if got.shape == want.shape:
            widened.append(got)
        else:
            pads = [(0, w - g) for g, w in zip(got.shape, want.shape)]
            widened.append(jnp.pad(got, pads))
    cache = tuple(widened)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tokens]
    pos0 = T if model_cfg.family != "vlm" else T + model_cfg.vision_patches

    if tune_program:
        # The decode evaluator replays the *current* decoding state; its
        # outputs are discarded, so measurement is side-effect-free.
        t_init = time.perf_counter()
        decode_state.update(cache=cache, tokens=tokens, pos=jnp.int32(pos0))
        max_len_b = coordinator.lifecycle.bucket_length(max_len)
        decode_ev = Evaluator(
            mode="real", real_runs=1, warmup=1,
            make_args=lambda: (params, decode_state["cache"],
                               decode_state["tokens"], decode_state["pos"]))
        decode = coordinator.register(
            "serve_decode", _decode_compilette(model_cfg, max_len_b),
            decode_ev,
            specialization={"max_len": max_len, "batch": B},
            reference_fn=decode,
        )
        decode.tuner.evaluator.make_args = decode_ev.make_args
        tune_init_s += time.perf_counter() - t_init

    t1 = time.perf_counter()
    for i in range(serve.max_new_tokens - 1):
        t_step = time.perf_counter()
        logits, cache = decode(params, cache, tokens, jnp.int32(pos0 + i))
        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tokens)
        if tuning:
            if credit_busy:
                # sync before crediting: jax dispatch is asynchronous, so
                # without it the credited interval would be the enqueue
                # time (µs) while the device executes inside the final
                # block_until_ready — and a busy-time budget would starve
                # exactly the kernel tuning this credit exists to fund
                jax.block_until_ready(tokens)
                coordinator.observe_busy(time.perf_counter() - t_step)
            if tune_program:
                decode_state.update(
                    cache=cache, tokens=tokens, pos=jnp.int32(pos0 + i + 1))
            coordinator.maybe_pump()
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t1

    generated = jnp.concatenate(out_tokens, axis=1)
    n_new = generated.shape[1]
    out = {
        "tokens": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens_per_s": B * n_new / t_decode if t_decode > 0 else 0.0,
    }
    if tuning:
        coordinator.save_registry()
        # Lifecycle pass at request end: converged tuners release the
        # evaluator closures pinning this request's params/batch/cache,
        # and tuners idle past the eviction horizon are unregistered.
        coordinator.sweep()
        out["tune_init_s"] = tune_init_s
        out["kernel_tuning"] = serve.kernel_tuning
        out["autotune"] = coordinator.stats()
    return out
