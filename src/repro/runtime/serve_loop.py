"""Serving runtime: batched prefill + greedy decode with KV/state cache."""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import build_model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


def generate(
    model_cfg: ModelConfig,
    batch: dict[str, Any],
    serve: ServeConfig | None = None,
) -> dict[str, Any]:
    """Prefill the prompt batch, then decode ``max_new_tokens`` greedily."""
    serve = serve or ServeConfig()
    model = build_model(model_cfg)
    from repro.models.params import init_tree
    params = batch.pop("params", None)
    if params is None:
        params = init_tree(model.param_defs(), jax.random.PRNGKey(serve.seed),
                           model_cfg.param_dtype)

    B, T = batch["tokens"].shape
    max_len = T + serve.max_new_tokens
    if model_cfg.family == "vlm":
        max_len += model_cfg.vision_patches

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    # widen KV caches to max_len where the family uses positional caches
    full = model.init_cache(B, max_len)
    widened = []
    for got, want in zip(cache, full):
        if got.shape == want.shape:
            widened.append(got)
        else:
            pads = [(0, w - g) for g, w in zip(got.shape, want.shape)]
            widened.append(jnp.pad(got, pads))
    cache = tuple(widened)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tokens]
    pos0 = T if model_cfg.family != "vlm" else T + model_cfg.vision_patches
    t1 = time.perf_counter()
    for i in range(serve.max_new_tokens - 1):
        logits, cache = decode(params, cache, tokens, jnp.int32(pos0 + i))
        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t1

    generated = jnp.concatenate(out_tokens, axis=1)
    n_new = generated.shape[1]
    return {
        "tokens": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens_per_s": B * n_new / t_decode if t_decode > 0 else 0.0,
    }
