"""Architecture registry: --arch <id> resolves through REGISTRY."""

from repro.configs.base import (
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    ModelConfig, ShapeSpec,
)

from repro.configs.llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT
from repro.configs.qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE
from repro.configs.command_r_35b import CONFIG as COMMAND_R
from repro.configs.deepseek_coder_33b import CONFIG as DEEPSEEK_CODER
from repro.configs.qwen2_5_32b import CONFIG as QWEN2_5
from repro.configs.deepseek_7b import CONFIG as DEEPSEEK_7B
from repro.configs.rwkv6_1_6b import CONFIG as RWKV6
from repro.configs.qwen2_vl_7b import CONFIG as QWEN2_VL
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.hymba_1_5b import CONFIG as HYMBA

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        LLAMA4_SCOUT, QWEN3_MOE, COMMAND_R, DEEPSEEK_CODER, QWEN2_5,
        DEEPSEEK_7B, RWKV6, QWEN2_VL, WHISPER_TINY, HYMBA,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
