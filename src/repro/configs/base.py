"""Model/runtime configuration dataclasses.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; reduced smoke-test variants are derived via
``.reduced()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512      # GShard dispatch group length (tokens)
    # --- SSM / RWKV ---
    ssm_state: int = 16
    ssm_conv: int = 4
    rwkv_head_size: int = 64
    # --- attention details ---
    qkv_bias: bool = False
    use_rope: bool = True          # False: absolute positions (whisper)
    rope_theta: float = 1e6
    window: int | None = None      # sliding-window attention (tokens)
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE
    parallel_block: bool = False   # command-r style parallel attn+FFN
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | gelu | sqrelu
    logit_softcap: float | None = None
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 1500
    # --- vlm ---
    vision_patches: int = 0        # stub frontend: # of precomputed patches
    # --- numerics / runtime ---
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: str = "dots"            # none | dots | full
    attn_q_chunk: int = 512    # §Perf H8b: larger chunks cut kv re-reads
    attn_k_chunk: int = 1024
    decode_k_chunk: int = 4096     # flash-decoding KV-chunk (serve tuning)
    scan_chunk: int = 128          # rwkv/ssm chunk length
    attn_impl: str = "chunked"     # chunked | ref | pallas
    attn_scores_f32: bool = True   # False: bf16 score blocks (models the
                                   # Pallas kernel's VMEM-resident scores)
    max_decode_len: int = 32768
    microbatches: int = 0          # grad-accumulation steps (0 = auto)

    # ------------------------------------------------------------- derived
    @property
    def d_qkv(self) -> int:
        return self.n_heads * self.d_head

    @property
    def supports_long_decode(self) -> bool:
        """O(1)-state decode (SSM/hybrid) — eligible for long_500k."""
        return self.family in ("rwkv", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def n_params(self) -> int:
        """Total parameter count (analytic)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * d
        # whisper ties the unembedding and adds a learned decoder pos table
        out_head = V * d if self.family != "encdec" \
            else self.max_decode_len * d
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "hybrid"):
            attn = d * self.d_qkv + 2 * d * self.n_kv_heads * self.d_head \
                + self.d_qkv * d
            per_layer += attn
        if self.family == "hybrid":
            # mamba branch: in/out proj + ssm params
            di = self.d_model
            per_layer += 2 * d * di + di * d + 2 * di * self.ssm_state * 2
        if self.family == "rwkv":
            per_layer += 6 * d * d          # r,k,v,g,o,w projections
            per_layer += 2 * d * ff         # channel mix (sq-relu)
        elif self.family == "moe":
            n_mat = 3 if self.act == "swiglu" else 2
            per_layer += self.n_experts * n_mat * d * ff + d * self.n_experts
            per_layer += self.n_shared_experts * n_mat * d * ff
        else:
            n_mat = 3 if self.act == "swiglu" else 2
            per_layer += n_mat * d * ff
        total = emb + out_head + L * per_layer
        if self.family == "encdec":
            enc_per = d * self.d_qkv * 2 + 2 * d * self.n_kv_heads * self.d_head \
                + 2 * d * ff
            total += self.enc_layers * enc_per
            total += L * (d * self.d_qkv + 2 * d * self.n_kv_heads * self.d_head
                          + self.d_qkv * d)  # cross-attention
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE discounts inactive experts)."""
        if self.family != "moe":
            return self.n_params()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        n_mat = 3 if self.act == "swiglu" else 2
        inactive = self.n_experts - (self.top_k + self.n_shared_experts)
        return self.n_params() - L * inactive * n_mat * d * ff

    def reduced(self, **overrides: Any) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_group_size=32,
            ssm_state=8,
            rwkv_head_size=16,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=32,
            vision_patches=min(self.vision_patches, 16) if self.vision_patches else 0,
            window=min(self.window, 32) if self.window else None,
            mrope_sections=(4, 2, 2) if self.mrope_sections else None,
            attn_q_chunk=32,
            attn_k_chunk=32,
            scan_chunk=16,
            max_decode_len=128,
            microbatches=0,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
