"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).
[arXiv:2409.12191; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),   # sums to d_head/2 = 64
    vision_patches=1024,
    rope_theta=1e6,
    act="swiglu",
    microbatches=8,   # fits 16 GB/device on the 16x16 mesh (EXPERIMENTS §Dry-run)
)
