"""command-r-35b [dense] — GQA kv=8, parallel attn+FFN block, layernorm,
no bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22528,
    vocab=256000,
    parallel_block=True,
    norm="layernorm",
    rope_theta=8e6,
    act="swiglu",
)
