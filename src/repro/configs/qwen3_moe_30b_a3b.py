"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, fine-grained d_ff=768.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=64,
    d_ff=768,
    vocab=151936,
    n_experts=128,
    top_k=8,
    rope_theta=1e6,
    act="swiglu",
    microbatches=8,   # fits 16 GB/device on the 16x16 mesh (EXPERIMENTS §Dry-run)
)
