"""rwkv6-1.6b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # derived: d_model / rwkv_head_size
    n_kv_heads=32,
    d_head=64,
    rwkv_head_size=64,
    d_ff=7168,
    vocab=65536,
    act="sqrelu",
)
