"""hymba-1.5b [hybrid] — parallel attention + mamba heads, sliding-window
attention + SSM state (O(1) decode). [arXiv:2411.13676; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    window=2048,
    rope_theta=1e4,
    act="swiglu",
)
