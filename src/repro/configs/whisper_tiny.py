"""whisper-tiny [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings). [arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    enc_layers=4,
    enc_frames=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_head=64,
    d_ff=1536,
    vocab=51865,
    norm="layernorm",
    use_rope=False,
    act="gelu",
)
