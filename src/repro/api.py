"""One front door: the ``repro.tune`` session API.

The paper's pitch is that online auto-tuning pays off only when it is
cheap to *adopt* — deployed directly at the level of machine-code
generation, with 0.2–4.2 % overhead and no re-architecting of the
application. After PRs 1–4 this repo had grown four entry points
(:class:`~repro.core.OnlineAutotuner`, ``static_autotune``,
``TuningCoordinator.register``, ``KernelTuningPlane``) and three CLIs
re-declaring the same strategy/budget/SLO/bucketing knobs. This module
collapses them into one declarative surface (cf. the Kernel Tuning
Toolkit's single dynamic-tuning API, arXiv:1910.08498, and "Tuning the
Tuner"'s one-place searcher configuration):

  * :class:`TuningConfig` — every tuning knob, once, as data; built
    programmatically, :meth:`TuningConfig.from_env` (``REPRO_TUNE_*``),
    or :meth:`TuningConfig.from_flags` / :meth:`TuningConfig.add_flags`
    (so CLIs declare the canonical flag set in one call);
  * :class:`TuningSession` — owns exactly one
    :class:`~repro.runtime.coordinator.TuningCoordinator` (shared
    budget, warm-start registry, generation cache, async pipeline) and
    at most one :class:`~repro.runtime.kernel_plane.KernelTuningPlane`;
  * :meth:`TuningSession.tune` / the :func:`tuned` decorator — wrap any
    jax callable into a coordinator-managed
    :class:`~repro.runtime.coordinator.ManagedTuner` handle: the
    application just keeps calling its function;
  * :meth:`TuningSession.attach_kernels` — hierarchical registration of
    a model's constituent catalog kernels (subsumes the serve/train
    plane wiring);
  * :meth:`TuningSession.scope` — the one context manager serve/train
    enter: installs the kernel plane for model code, re-entrant, and
    (for sessions that own their lifetime) closes exactly once at the
    outermost exit.

Legacy constructors (``make_serve_coordinator``, the per-loop
coordinator wiring) delegate here behind ``DeprecationWarning``\\ s; the
ROADMAP's multi-host registry and model-based search strategies plug
into this surface without touching call sites again.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import os
import threading
from typing import Any, Callable, Mapping

from repro.core.compilette import (
    Compilette,
    GenerationCache,
    device_free_memory_bytes,
)
from repro.core.decision import LatencyHeadroomGate, RegenerationPolicy
from repro.core.evaluator import Evaluator
from repro.core.gate import GATE_MODES
from repro.core.tuning_space import TuningSpace
from repro.runtime.coordinator import ManagedTuner, TuningCoordinator
from repro.runtime.kernel_plane import (
    KernelTuningPlane,
    parse_kernel_strategies,
    use_kernel_plane,
)
from repro.runtime.lifecycle import TunerLifecycle, TunerState

__all__ = [
    "COMPILE_BACKENDS",
    "KERNEL_TUNING_MODES",
    "TunedFunction",
    "TuningConfig",
    "TuningSession",
    "apply_tuning_kwargs",
    "default_session",
    "install_tuning_aliases",
    "serve_tuning_defaults",
    "set_default_session",
    "train_tuning_defaults",
    "tune",
    "tuned",
]

KERNEL_TUNING_MODES = ("off", "program", "kernel", "both")
# compile-farm backends: "auto" keeps the clock-based pick (virtual clock
# -> deterministic "manual" batches, real clock -> worker threads);
# "process" opts into child-process compiles for GIL-free serving.
COMPILE_BACKENDS = ("auto", "thread", "process", "manual")


def _canon(spec: Mapping[str, Any]) -> str:
    return json.dumps(dict(spec), sort_keys=True, separators=(",", ":"))


def _parse_workers(value: Any) -> "int | str":
    """``--compile-workers`` / env value: a pool size M, or \"auto\"."""
    s = str(value).strip()
    if s.lower() == "auto":
        return "auto"
    return int(s)


def _resolve_backend(spec: Any) -> Any:
    """A :class:`~repro.core.persistence.RegistryBackend` from config.

    ``None``/empty stays local-only; ``"shared:<path>"`` (or a bare
    path) builds a :class:`~repro.core.persistence.SharedFileBackend`
    over that file. Non-string values are assumed to already BE backend
    objects (e.g. a ``FleetBus`` handed to :class:`TuningSession`) and
    pass through.
    """
    if spec is None:
        return None
    if not isinstance(spec, str):
        return spec
    s = spec.strip()
    if not s:
        return None
    from repro.core.persistence import SharedFileBackend

    scheme, sep, rest = s.partition(":")
    if sep and scheme == "shared" and rest:
        return SharedFileBackend(rest)
    if sep and scheme in ("local", "file") and rest:
        return SharedFileBackend(rest)
    return SharedFileBackend(s)   # bare path


# ============================================================== TuningConfig
@dataclasses.dataclass
class TuningConfig:
    """Every tuning knob of a session, declaratively.

    One instance configures program-level tuners, the kernel plane, the
    shared budget, the warm-start registry and the async generation
    pipeline — the knobs that previously had to be re-plumbed through
    ``ServeConfig``/``TrainLoopConfig`` and three CLIs.
    """

    enabled: bool = True              # master switch (CLI: --autotune)
    strategy: str = "two_phase"       # default search strategy (registry name)
    strategies: dict[str, str] | None = None   # per-kernel overrides
    max_overhead: float = 0.05        # budget: fraction of app/busy time
    invest: float = 0.10              # budget: reinvested fraction of gains
    budget_from: str = "wall"         # "wall" (paper) | "busy" (serving)
    charge_init: bool = False         # budget the reference measurement
    slo_s: float | None = None        # per-call latency SLO (headroom gate)
    slo_quantile: float | None = None  # e.g. 0.99: gate on p99, not mean
    seq_buckets: bool = True          # pow2-bucket seq/max_len tuner keys
    idle_evict_s: float | None = 300.0  # retire tuners idle this long
    registry_path: str | None = None  # warm-start across restarts
    pump_every: int = 8               # app calls between tuning slots
    async_generation: bool = True     # compile variants off the hot path
    prefetch: int = 1                 # speculative compiles per slot
    compile_workers: "int | str" = 1  # compile-farm pool size (M) or "auto"
    compile_backend: str = "auto"     # auto | thread | process | manual
    kernel_tuning: str = "program"    # off | program | kernel | both
    cache_entries: int | None = 256   # generation-cache entry bound
    cache_bytes: int | None = None    # generation-cache byte bound
    gate_mode: str = "off"            # off | check | canary (trusted swaps)
    canary_fraction: float = 0.25     # fraction of calls a canary serves
    canary_calls: int = 8             # clean canary calls before promotion
    gate_rtol: float | None = None    # oracle tolerance overrides
    gate_atol: float | None = None    # (None = per-kernel catalog values)
    # fleet fabric: N replicas partition exploration and share a registry
    # backend ("shared:<path>" or a bare path -> SharedFileBackend; pass
    # backend OBJECTS — e.g. a FleetBus — to TuningSession directly)
    replica_id: int = 0               # this replica's index in the fleet
    replica_count: int = 1            # fleet size (1 = no partitioning)
    registry_backend: str | None = None   # shared backend spec
    sync_every_s: float | None = 1.0  # fleet sync cadence (None = every pump)
    # transfer plane: on a fingerprint miss, seed the search with the
    # top-k foreign bests ranked by device-trait similarity; seeds flow
    # through the gate/canary path as CANDIDATEs, never blind incumbents
    transfer: bool = False            # cross-device transfer seeding
    transfer_top_k: int = 3           # foreign bests injected per miss
    min_similarity: float = 0.75      # trait-similarity floor in (0, 1]

    def __post_init__(self) -> None:
        if self.kernel_tuning not in KERNEL_TUNING_MODES:
            raise ValueError(
                f"kernel_tuning must be one of {KERNEL_TUNING_MODES}, "
                f"got {self.kernel_tuning!r}")
        if self.budget_from not in ("wall", "busy"):
            raise ValueError(
                f"budget_from must be 'wall' or 'busy', "
                f"got {self.budget_from!r}")
        if self.compile_backend not in COMPILE_BACKENDS:
            raise ValueError(
                f"compile_backend must be one of {COMPILE_BACKENDS}, "
                f"got {self.compile_backend!r}")
        if self.compile_workers != "auto" and (
                not isinstance(self.compile_workers, int)
                or self.compile_workers < 1):
            raise ValueError(
                f"compile_workers must be >= 1 or 'auto', "
                f"got {self.compile_workers!r}")
        if self.replica_count < 1:
            raise ValueError(
                f"replica_count must be >= 1, got {self.replica_count}")
        if not 0 <= self.replica_id < self.replica_count:
            raise ValueError(
                f"replica_id must be in [0, {self.replica_count}), "
                f"got {self.replica_id}")
        if self.sync_every_s is not None and self.sync_every_s < 0:
            raise ValueError(
                f"sync_every_s must be >= 0 or None, got {self.sync_every_s}")
        if self.gate_mode not in GATE_MODES:
            raise ValueError(
                f"gate_mode must be one of {GATE_MODES}, "
                f"got {self.gate_mode!r}")
        if not 0.0 < self.canary_fraction <= 1.0:
            raise ValueError(
                f"canary_fraction must be in (0, 1], "
                f"got {self.canary_fraction}")
        if self.canary_calls < 1:
            raise ValueError(
                f"canary_calls must be >= 1, got {self.canary_calls}")
        if self.transfer_top_k < 1:
            raise ValueError(
                f"transfer_top_k must be >= 1, got {self.transfer_top_k}")
        if not 0.0 < self.min_similarity <= 1.0:
            raise ValueError(
                f"min_similarity must be in (0, 1], "
                f"got {self.min_similarity}")

    # -------------------------------------------------------- derived views
    @property
    def active(self) -> bool:
        """Tuning actually happens (enabled and not mode ``off``)."""
        return self.enabled and self.kernel_tuning != "off"

    @property
    def tune_program(self) -> bool:
        return self.active and self.kernel_tuning in ("program", "both")

    @property
    def tune_kernels(self) -> bool:
        return self.active and self.kernel_tuning in ("kernel", "both")

    def policy(self) -> RegenerationPolicy:
        return RegenerationPolicy(
            max_overhead_frac=self.max_overhead,
            invest_frac=self.invest,
            budget_from=self.budget_from,
            charge_init=self.charge_init,
            headroom=(LatencyHeadroomGate(
                slo_s=self.slo_s, slo_quantile=self.slo_quantile)
                if self.slo_s else None),
        )

    def lifecycle(self) -> TunerLifecycle:
        return TunerLifecycle(seq_buckets=self.seq_buckets,
                              idle_evict_s=self.idle_evict_s)

    # ------------------------------------------------------------------ env
    # field → parser; fields absent here parse as plain strings
    _BOOL_FIELDS = ("enabled", "charge_init", "seq_buckets",
                    "async_generation", "transfer")
    _FLOAT_FIELDS = ("max_overhead", "invest", "canary_fraction",
                     "min_similarity")
    _OPT_FLOAT_FIELDS = ("slo_s", "slo_quantile", "idle_evict_s",
                         "gate_rtol", "gate_atol", "sync_every_s")
    _INT_FIELDS = ("pump_every", "prefetch", "canary_calls",
                   "replica_id", "replica_count", "transfer_top_k")
    _OPT_INT_FIELDS = ("cache_entries", "cache_bytes")
    _OPT_STR_FIELDS = ("registry_path", "registry_backend")
    # environment/CLI spellings that map onto differently named fields
    _FIELD_ALIASES = {"autotune": "enabled",
                      "kernel_strategies": "strategies",
                      "gate": "gate_mode",
                      "sync_every": "sync_every_s",
                      "transfer_k": "transfer_top_k"}

    @classmethod
    def _parse_field(cls, field: str, raw: str) -> Any:
        s = raw.strip()
        none = s == "" or s.lower() == "none"
        if field in cls._BOOL_FIELDS:
            return s.lower() in ("1", "true", "yes", "on")
        if field in cls._FLOAT_FIELDS:
            return float(s)
        if field in cls._OPT_FLOAT_FIELDS:
            return None if none else float(s)
        if field in cls._INT_FIELDS:
            return int(s)
        if field in cls._OPT_INT_FIELDS:
            return None if none else int(s)
        if field in cls._OPT_STR_FIELDS:
            return None if none else s
        if field == "compile_workers":
            return _parse_workers(s)
        if field == "strategies":
            items = [i for i in s.replace(",", " ").split() if i]
            try:
                return parse_kernel_strategies(items)
            except SystemExit as e:
                # the parser's CLI-flavoured SystemExit is wrong for a
                # config/env code path: surface the same message as the
                # contract every other bad env value follows
                raise ValueError(
                    f"bad kernel strategies {raw!r}: {e}") from None
        return s

    @classmethod
    def from_env(
        cls,
        environ: Mapping[str, str] | None = None,
        *,
        base: "TuningConfig | None" = None,
        prefix: str = "REPRO_TUNE_",
    ) -> "TuningConfig":
        """Config from ``REPRO_TUNE_*`` variables (field names uppercased).

        ``REPRO_TUNE_STRATEGY=greedy REPRO_TUNE_MAX_OVERHEAD=0.1`` etc.;
        booleans accept 1/true/yes/on, per-kernel strategies are
        ``REPRO_TUNE_STRATEGIES="matmul=greedy,attention=random"``.
        Unknown ``REPRO_TUNE_*`` keys raise (a typo'd knob must not be
        silently ignored).
        """
        env = os.environ if environ is None else environ
        known = {f.name for f in dataclasses.fields(cls)}
        updates: dict[str, Any] = {}
        for key in sorted(env):
            if not key.startswith(prefix):
                continue
            field = key[len(prefix):].lower()
            field = cls._FIELD_ALIASES.get(field, field)
            if field not in known:
                raise ValueError(
                    f"unknown tuning variable {key!r}: no TuningConfig "
                    f"field {field!r}")
            updates[field] = cls._parse_field(field, env[key])
        return dataclasses.replace(base or cls(), **updates)

    # ---------------------------------------------------------------- flags
    @staticmethod
    def add_flags(parser: Any,
                  base: "TuningConfig | None" = None) -> Any:
        """Declare the canonical tuning flags on an argparse parser.

        CLIs call this once instead of re-declaring the knob set; the
        ``base`` config supplies the defaults (so serve and train CLIs
        can differ only in their base). Returns the parser.
        """
        from repro.core.explorer import available_strategies

        base = base or TuningConfig(enabled=False)
        g = parser.add_argument_group("online auto-tuning (repro.tune)")
        g.add_argument("--autotune", action="store_true",
                       default=base.enabled,
                       help="tune online under the session budget")
        g.add_argument("--strategy", default=base.strategy,
                       choices=available_strategies(),
                       help="search strategy for every tuner")
        g.add_argument("--kernel-tuning", default=base.kernel_tuning,
                       choices=list(KERNEL_TUNING_MODES),
                       help="tuning granularity: whole step-programs, "
                            "individual Pallas kernels, or both levels "
                            "hierarchically under one shared budget")
        g.add_argument("--kernel-strategy", action="append", default=[],
                       metavar="KERNEL=STRATEGY",
                       help="per-kernel search strategy override "
                            "(repeatable), e.g. matmul=greedy")
        g.add_argument("--tune-overhead", type=float,
                       default=base.max_overhead,
                       help="tuning overhead cap (fraction of app time)")
        g.add_argument("--tune-invest", type=float, default=base.invest,
                       help="fraction of gained time reinvested")
        g.add_argument("--registry", default=base.registry_path,
                       help="tuned-point registry path (warm-start)")
        g.add_argument("--slo", type=float, default=base.slo_s,
                       help="per-step latency SLO in seconds "
                            "(headroom-gates tuning)")
        g.add_argument("--slo-quantile", type=float,
                       default=base.slo_quantile,
                       help="gate on this latency quantile (e.g. 0.99 "
                            "for p99) instead of the per-call EWMA; "
                            "needs --slo")
        g.add_argument("--seq-buckets", dest="seq_buckets",
                       action="store_true", default=base.seq_buckets,
                       help="pow2-bucket seq/max_len tuner keys")
        g.add_argument("--no-seq-buckets", dest="seq_buckets",
                       action="store_false",
                       help="one tuner per exact shape")
        g.add_argument("--sync-generation", dest="async_generation",
                       action="store_false",
                       default=base.async_generation,
                       help="compile candidate variants inline on the "
                            "hot path (paper's original synchronous "
                            "cycle) instead of the background pipeline")
        g.add_argument("--prefetch", type=int, default=base.prefetch,
                       help="speculative compiles per tuning slot (0=off)")
        g.add_argument("--compile-workers", type=_parse_workers,
                       default=base.compile_workers,
                       help="compile-farm pool size: background variant "
                            "compiles running concurrently, or 'auto' "
                            "to grow under backlog and shrink when idle")
        g.add_argument("--compile-backend", default=base.compile_backend,
                       choices=list(COMPILE_BACKENDS),
                       help="compile-farm backend: auto picks threads "
                            "(or deterministic manual batches under a "
                            "virtual clock); process isolates compiles "
                            "in child processes")
        g.add_argument("--gate-mode", default=base.gate_mode,
                       choices=list(GATE_MODES),
                       help="trusted swaps: check gates every variant "
                            "against the kernel's oracle before it may "
                            "serve; canary additionally stages promotion "
                            "behind a serving canary with auto-rollback")
        g.add_argument("--canary-fraction", type=float,
                       default=base.canary_fraction,
                       help="fraction of production calls a canary "
                            "variant serves before promotion")
        g.add_argument("--canary-calls", type=int,
                       default=base.canary_calls,
                       help="clean canary calls required for promotion")
        g.add_argument("--gate-rtol", type=float, default=base.gate_rtol,
                       help="override the per-kernel oracle rtol")
        g.add_argument("--gate-atol", type=float, default=base.gate_atol,
                       help="override the per-kernel oracle atol")
        g.add_argument("--replica-id", type=int, default=base.replica_id,
                       help="fleet: this replica's index in [0, "
                            "replica-count)")
        g.add_argument("--replica-count", type=int,
                       default=base.replica_count,
                       help="fleet: replicas partitioning exploration "
                            "over a shared registry backend")
        g.add_argument("--registry-backend", default=base.registry_backend,
                       help="fleet: shared registry backend, "
                            "'shared:<path>' (lock-file protected JSON "
                            "shared by every replica)")
        g.add_argument("--sync-every", type=float, dest="sync_every_s",
                       default=base.sync_every_s,
                       help="fleet: seconds between registry syncs")
        g.add_argument("--transfer", action="store_true",
                       default=base.transfer,
                       help="transfer plane: on a fingerprint miss, seed "
                            "the search with foreign bests from trait-"
                            "similar devices (gated CANDIDATEs)")
        g.add_argument("--transfer-top-k", type=int,
                       dest="transfer_top_k",
                       default=base.transfer_top_k,
                       help="foreign bests injected per fingerprint miss")
        g.add_argument("--min-similarity", type=float,
                       dest="min_similarity",
                       default=base.min_similarity,
                       help="device-trait similarity floor in (0, 1] "
                            "below which foreign bests are not seeded")
        return parser

    @classmethod
    def from_flags(cls, args: Any,
                   base: "TuningConfig | None" = None) -> "TuningConfig":
        """Config from an argparse namespace built by :meth:`add_flags`.

        ``base`` supplies the fields that have no flag (budget source,
        init charging, eviction horizon, cache bounds) — pass the same
        base given to ``add_flags``.
        """
        if (getattr(args, "slo_quantile", None) is not None
                and getattr(args, "slo", None) is None):
            raise SystemExit(
                "--slo-quantile has no effect without --slo (the "
                "headroom gate only exists when an SLO is set)")
        base = base or cls(enabled=False)
        strategies = parse_kernel_strategies(
            list(getattr(args, "kernel_strategy", []) or []))
        if strategies is None:
            # no --kernel-strategy flags: inherit the base overrides,
            # like every other flag inherits its base default
            strategies = base.strategies
        return dataclasses.replace(
            base,
            enabled=args.autotune,
            strategy=args.strategy,
            kernel_tuning=args.kernel_tuning,
            strategies=strategies,
            max_overhead=args.tune_overhead,
            invest=args.tune_invest,
            registry_path=args.registry,
            slo_s=args.slo,
            slo_quantile=args.slo_quantile,
            seq_buckets=args.seq_buckets,
            async_generation=args.async_generation,
            prefetch=args.prefetch,
            compile_workers=args.compile_workers,
            compile_backend=args.compile_backend,
            gate_mode=args.gate_mode,
            canary_fraction=args.canary_fraction,
            canary_calls=args.canary_calls,
            gate_rtol=args.gate_rtol,
            gate_atol=args.gate_atol,
            replica_id=args.replica_id,
            replica_count=args.replica_count,
            registry_backend=args.registry_backend,
            sync_every_s=args.sync_every_s,
            transfer=args.transfer,
            transfer_top_k=args.transfer_top_k,
            min_similarity=args.min_similarity,
        )


# ------------------------------------------------------ per-regime defaults
def serve_tuning_defaults() -> TuningConfig:
    """Serving-grade base config: strict cap as a fraction of BUSY time,
    reference measurements charged, pow2 bucketing + idle eviction.

    Lives here (not in the jax-heavy serve loop) so CLIs can seed their
    flags before importing anything expensive.
    """
    return TuningConfig(
        enabled=False, max_overhead=0.05, invest=0.10,
        budget_from="busy", charge_init=True, seq_buckets=True,
        idle_evict_s=300.0, pump_every=4, async_generation=True,
        prefetch=1, kernel_tuning="program")


def train_tuning_defaults() -> TuningConfig:
    """Training-grade base config: generous overhead for short demo runs,
    wall-clock budget, fixed-shape step-programs (no bucketing, no
    eviction), tight pump cadence."""
    return TuningConfig(
        enabled=False, max_overhead=0.20, invest=0.5,
        budget_from="wall", charge_init=False, seq_buckets=False,
        idle_evict_s=None, pump_every=2, async_generation=True,
        prefetch=1, kernel_tuning="program")


# -------------------------------------------------- legacy field aliasing
def install_tuning_aliases(cls: type, aliases: Mapping[str, str]) -> type:
    """Install legacy flat-field properties delegating into ``.tuning``.

    Shared by ``ServeConfig``/``TrainLoopConfig``: each legacy name
    becomes a read/write property over the embedded :class:`TuningConfig`
    field, so pre-PR-5 call sites keep working against ONE
    implementation of the aliasing behaviour.
    """
    def make(field: str) -> property:
        def _get(self: Any) -> Any:
            return getattr(self.tuning, field)

        def _set(self: Any, value: Any) -> None:
            setattr(self.tuning, field, value)

        return property(_get, _set)

    for legacy, field in aliases.items():
        setattr(cls, legacy, make(field))
    return cls


def apply_tuning_kwargs(tuning: TuningConfig, aliases: Mapping[str, str],
                        legacy: Mapping[str, Any], owner: str) -> None:
    """Apply legacy flat constructor kwargs onto an embedded config."""
    unknown = set(legacy) - set(aliases)
    if unknown:
        raise TypeError(
            f"{owner} got unexpected keyword arguments {sorted(unknown)}")
    for key, value in legacy.items():
        setattr(tuning, aliases[key], value)


# ============================================================ TunedFunction
class TunedFunction:
    """A jax callable wrapped into coordinator-managed tuner handles.

    Built by :meth:`TuningSession.tune` / the :func:`tuned` decorator.
    The tuning-space point's keys are passed to ``fn`` as keyword
    arguments **closed over at generation time** (trace-time constants —
    the deGoal ``#(...)`` inlining analogue), so each point compiles to
    its own specialized executable. Registration is lazy: the first call
    captures live arguments, so the register-time reference measurement
    (and every later evaluation, until the lifecycle releases the
    closure) runs on real traffic. ``spec_from(*args)`` keys separate
    handles per run-time-constant cell (shape-like keys are pow2-bucketed
    by the session lifecycle), exactly like the kernel plane.
    """

    def __init__(
        self,
        session: "TuningSession",
        fn: Callable[..., Any],
        *,
        space: "TuningSpace | Callable[[dict], TuningSpace]",
        name: str | None = None,
        spec: Mapping[str, Any] | None = None,
        spec_from: Callable[..., Mapping[str, Any]] | None = None,
        evaluator: Any | None = None,
        reference_fn: Callable[..., Any] | None = None,
        reference_score_s: float | None = None,
        strategy: str | None = None,
        jit: bool = True,
        gen_cost_s: "float | Callable[..., float] | None" = None,
        cache_token: str | None = None,
    ) -> None:
        functools.update_wrapper(self, fn)
        self._session = session
        self._fn = fn
        self._space = space
        self._name = name or getattr(fn, "__name__", "tuned")
        self._spec = dict(spec or {})
        self._spec_from = spec_from
        self._evaluator = evaluator
        self._reference_fn = reference_fn
        self._reference_score_s = reference_score_s
        self._strategy = strategy
        self._jit = bool(jit)
        self._gen_cost_s = gen_cost_s
        self._cache_token = cache_token
        self._handles: dict[str, ManagedTuner] = {}
        self._live_args: dict[str, tuple] = {}

    # ------------------------------------------------------------ generation
    def _generate(self, point: dict, **sp: Any) -> Callable[..., Any]:
        del sp  # run-time constants live in the point closure / fn body
        pt = dict(point)
        call = functools.partial(self._fn, **pt)
        if self._jit:
            import jax

            call = jax.jit(call)

        def variant(*args: Any) -> Any:
            return call(*args)

        variant.point = pt   # virtual evaluators read the point back
        return variant

    # --------------------------------------------------------------- handles
    def _remember_or_release(self, key: str, handle: ManagedTuner,
                             args: tuple) -> None:
        """Pin live args only while the handle can still evaluate."""
        if (handle.state is TunerState.ACTIVE
                and not handle.tuner.explorer.finished):
            self._live_args[key] = args
        else:
            self._live_args.pop(key, None)

    def _handle_for(self, args: tuple) -> ManagedTuner:
        coord = self._session.coordinator
        spec = dict(self._spec)
        if self._spec_from is not None:
            spec.update(self._spec_from(*args))
        bucketed = coord.lifecycle.bucket_specialization(dict(spec))
        key = _canon(bucketed)
        handle = self._handles.get(key)
        if handle is not None and handle.state is not TunerState.RETIRED:
            self._remember_or_release(key, handle, args)
            return handle
        space = self._space(dict(spec)) if callable(self._space) \
            else self._space
        comp = Compilette(self._name, space, self._generate,
                          gen_cost_s=self._gen_cost_s,
                          cache_token=self._cache_token)
        evaluator = self._evaluator or Evaluator(
            mode="real", real_runs=1, warmup=1,
            make_args=lambda k=key: self._live_args[k])
        # live args land BEFORE register(): the reference measurement
        # (and the warm-start re-validation) runs on real traffic
        self._live_args[key] = args
        handle = coord.register(
            self._name, comp, evaluator,
            specialization=spec,
            reference_fn=self._reference_fn,
            reference_score_s=self._reference_score_s,
            strategy=self._strategy)
        self._handles[key] = handle
        self._remember_or_release(key, handle, args)
        return handle

    def __call__(self, *args: Any) -> Any:
        handle = self._handle_for(args)
        out = handle(*args)
        # one front door: calling the function IS the application loop,
        # so the session paces its own tuning slots
        self._session.coordinator.maybe_pump()
        return out

    # ----------------------------------------------------------------- views
    @property
    def session(self) -> "TuningSession":
        return self._session

    @property
    def handle(self) -> ManagedTuner | None:
        """The most recently registered handle (the only one, commonly)."""
        return next(reversed(self._handles.values()), None) \
            if self._handles else None

    def handles(self) -> list[ManagedTuner]:
        return list(self._handles.values())

    @property
    def best_point(self) -> dict | None:
        h = self.handle
        return None if h is None else h.tuner.explorer.best_point

    @property
    def active_fn(self) -> Callable[..., Any] | None:
        h = self.handle
        return None if h is None else h.active_fn

    def stats(self) -> dict[str, Any]:
        if len(self._handles) == 1:
            return self.handle.stats()
        return {key: h.stats() for key, h in self._handles.items()}


# ============================================================= TuningSession
class TuningSession:
    """One coordinator + registry + generation cache + kernel plane.

    The single integration surface: serve/train loops, CLIs and user
    code configure a session from one :class:`TuningConfig` and get the
    whole PR 1–4 machinery — shared regeneration budget, gain-based
    fairness, warm starts, double-buffered generation, lifecycle
    bucketing/eviction, kernel-granular plane — behind three calls
    (:meth:`tune`, :meth:`attach_kernels`, :meth:`scope`).
    """

    def __init__(
        self,
        config: TuningConfig | None = None,
        *,
        coordinator: TuningCoordinator | None = None,
        clock: Callable[[], float] | None = None,
        registry: Any | None = None,
        generation_cache: GenerationCache | None = None,
        device: str | None = None,
        virtual: tuple | None = None,
        evaluator_factory: Callable[..., Any] | None = None,
        gen_cost_s: "float | Callable[..., float] | None" = None,
        interpret: bool = True,
        aot: bool = True,
        close_on_scope_exit: bool = False,
        compilette_hook: Callable[[Any], None] | None = None,
        registry_backend: Any | None = None,
    ) -> None:
        self.config = config if config is not None else TuningConfig()
        # kernel-plane construction kwargs (virtual backend for tests and
        # benchmarks), applied on the plane's first use; compilette_hook
        # runs on every freshly built kernel compilette — the
        # fault-injection replay harness uses it to install scripted
        # gate verdicts and wrapped generators
        self._plane_kwargs: dict[str, Any] = dict(
            virtual=virtual, evaluator_factory=evaluator_factory,
            gen_cost_s=gen_cost_s, interpret=interpret, aot=aot,
            compilette_hook=compilette_hook)
        self._scope_depth = 0
        self._close_on_scope_exit = bool(close_on_scope_exit)
        self._closed = False
        self._close_mu = threading.Lock()
        if coordinator is not None:
            # adopt an existing coordinator (legacy shims): the session
            # wraps it rather than building a second budget domain
            self.coordinator = coordinator
        else:
            cfg = self.config
            # the backend knob refines async generation: "auto" keeps the
            # coordinator's clock-based pick, an explicit backend forces
            # the farm mode (sync generation ignores both)
            async_generation: "bool | str" = (
                cfg.async_generation if cfg.compile_backend == "auto"
                else (cfg.async_generation and cfg.compile_backend))
            self.coordinator = TuningCoordinator(
                policy=cfg.policy(),
                registry=registry,
                registry_path=cfg.registry_path,
                device=device,
                clock=clock,
                pump_every=cfg.pump_every,
                lifecycle=cfg.lifecycle(),
                strategy=cfg.strategy,
                async_generation=async_generation,
                generation_cache=(
                    generation_cache if generation_cache is not None
                    else GenerationCache(
                        max_entries=cfg.cache_entries,
                        max_bytes=cfg.cache_bytes,
                        # live device-memory pressure shrinks the byte
                        # bound; on CPU/virtual backends the probe has no
                        # signal and the static bound applies unchanged
                        free_memory_fn=device_free_memory_bytes)),
                prefetch=cfg.prefetch,
                compile_workers=cfg.compile_workers,
                gate_mode=cfg.gate_mode,
                canary_fraction=cfg.canary_fraction,
                canary_calls=cfg.canary_calls,
                gate_rtol=cfg.gate_rtol,
                gate_atol=cfg.gate_atol,
                replica_id=cfg.replica_id,
                replica_count=cfg.replica_count,
                # a backend OBJECT passed to the session (FleetBus, a
                # custom RegistryBackend) wins over the config's string
                # spec; both plug into the same coordinator knob
                registry_backend=_resolve_backend(
                    registry_backend if registry_backend is not None
                    else cfg.registry_backend),
                sync_every_s=cfg.sync_every_s,
                transfer=cfg.transfer,
                transfer_top_k=cfg.transfer_top_k,
                min_similarity=cfg.min_similarity,
            )
        self.coordinator._session = self
        self._plane: KernelTuningPlane | None = getattr(
            self.coordinator, "_kernel_plane", None)

    # ------------------------------------------------------------- builders
    @classmethod
    def adopt(cls, coordinator: TuningCoordinator,
              config: TuningConfig | None = None) -> "TuningSession":
        """The session of ``coordinator``, created (once) on first use.

        Legacy call sites hold bare coordinators; this keeps them on the
        one-session-per-coordinator invariant. A fresh ``config``
        refreshes the session's declarative knobs (e.g. a request that
        switches ``kernel_tuning`` mode).
        """
        session = getattr(coordinator, "_session", None)
        if session is not None:
            if config is not None:
                session.config = config
            return session
        return cls(config, coordinator=coordinator)

    @classmethod
    def from_env(cls, environ: Mapping[str, str] | None = None,
                 *, base: TuningConfig | None = None,
                 **session_kwargs: Any) -> "TuningSession":
        """Session configured from ``REPRO_TUNE_*`` environment variables."""
        return cls(TuningConfig.from_env(environ, base=base),
                   **session_kwargs)

    @classmethod
    def from_flags(cls, args: Any, *, base: TuningConfig | None = None,
                   **session_kwargs: Any) -> "TuningSession":
        """Session from an argparse namespace (:meth:`TuningConfig.add_flags`)."""
        return cls(TuningConfig.from_flags(args, base=base),
                   **session_kwargs)

    # ------------------------------------------------------------ delegates
    @property
    def registry(self):
        return self.coordinator.registry

    @property
    def generation_cache(self) -> GenerationCache:
        return self.coordinator.generation_cache

    @property
    def plane(self) -> KernelTuningPlane | None:
        return self._plane

    @property
    def closed(self) -> bool:
        return self._closed

    def register(self, name: str, compilette: Compilette, evaluator: Any,
                 **kwargs: Any) -> ManagedTuner:
        """Register a pre-built compilette (program-level tuners)."""
        return self.coordinator.register(name, compilette, evaluator,
                                         **kwargs)

    def observe_busy(self, seconds: float) -> None:
        self.coordinator.observe_busy(seconds)

    def maybe_pump(self) -> bool:
        return self.coordinator.maybe_pump()

    def pump(self) -> bool:
        return self.coordinator.pump()

    def sweep(self):
        return self.coordinator.sweep()

    def save(self, path: str | None = None) -> None:
        """Flush current bests to the warm-start registry."""
        self.coordinator.save_registry(path)

    def stats(self) -> dict[str, Any]:
        return self.coordinator.stats()

    def start_thread(self, wake_period_s: float = 0.002) -> None:
        self.coordinator.start_thread(wake_period_s)

    # ----------------------------------------------------------------- tune
    def tune(self, fn: Callable[..., Any] | None = None, *,
             space: "TuningSpace | Callable[[dict], TuningSpace]",
             **kwargs: Any) -> "TunedFunction | Callable[..., TunedFunction]":
        """Wrap ``fn`` into a managed tuner handle (decorator-friendly).

        ``session.tune(fn, space=...)`` or::

            @session.tune(space=...)
            def kernel(x, *, chunk): ...

        The point's keys are passed to ``fn`` as keyword constants at
        generation time; see :class:`TunedFunction` for the spec/
        evaluator/reference options.
        """
        def wrap(f: Callable[..., Any]) -> TunedFunction:
            return TunedFunction(self, f, space=space, **kwargs)

        return wrap if fn is None else wrap(fn)

    # --------------------------------------------------------------- replay
    def replay(self, trace: Any,
               configs: Mapping[str, Any] | None = None,
               **kwargs: Any) -> dict[str, Any]:
        """Re-serve a scripted traffic trace, deterministically.

        The session-API entry to the :mod:`repro.bench.replay` harness:
        advances this session's (virtual) clock through the trace's
        arrivals, serves each request via the attached kernel handles
        (feeding per-call ``observe_latency`` through the managed
        tuners and ``observe_busy`` credits for scripted host work),
        and returns the per-tenant latency/speedup and session-level
        overhead report. See :func:`repro.bench.replay.replay`.
        """
        from repro.bench.replay import replay as _replay

        return _replay(self, trace, configs, **kwargs)

    # -------------------------------------------------------------- kernels
    def attach_kernels(self, model_cfg: Any, *, batch: int, seq: int,
                       max_len: int | None = None,
                       strategies: Mapping[str, str] | None = None,
                       ) -> KernelTuningPlane:
        """Register a model's constituent catalog kernels on the plane.

        Subsumes the PR-4 serve/train plane wiring: builds (or refreshes)
        the coordinator's one shared plane, pre-buckets the traffic
        extents, and registers every
        :func:`~repro.models.model.model_kernel_specs` kernel —
        including the decode-path ``decode_attention`` keyed per
        cache-length bucket when ``max_len`` is given. Untunable reduced
        shapes are skipped, not fatal. Idempotent per traffic cell.
        """
        from repro.models.model import model_kernel_specs

        cfg = self.config
        plane = KernelTuningPlane.shared(
            self.coordinator,
            strategies=(dict(strategies) if strategies is not None
                        else cfg.strategies),
            # program points own the chunk knobs in "both" mode: the two
            # levels must never fight over one knob
            adopt_points=cfg.kernel_tuning != "both",
            **self._plane_kwargs)
        lifecycle = self.coordinator.lifecycle
        seq_b = lifecycle.bucket_length(int(seq))
        max_b = lifecycle.bucket_length(int(max_len)) if max_len else None
        for name, spec in model_kernel_specs(
                model_cfg, batch=int(batch), seq=seq_b, max_len=max_b):
            plane.register_spec(name, spec, require=False)
        self._plane = plane
        return plane

    # ----------------------------------------------------------- scope/close
    @contextlib.contextmanager
    def scope(self):
        """The one context serve/train enter around their request/loop.

        Installs the kernel plane for model code (when kernels are
        attached), re-entrantly: nested scopes — a serve request inside
        an outer CLI scope — stack, and a session constructed with
        ``close_on_scope_exit=True`` closes exactly once, at the
        OUTERMOST exit (the regression PR 5's satellite fix covers).
        """
        if self._closed:
            raise RuntimeError("TuningSession is closed")
        self._scope_depth += 1
        ctx = (use_kernel_plane(self._plane) if self._plane is not None
               else contextlib.nullcontext())
        try:
            with ctx:
                yield self
        finally:
            self._scope_depth -= 1
            if self._scope_depth == 0 and self._close_on_scope_exit:
                self.close()

    def close(self) -> None:
        """Flush the registry and stop the pipeline — exactly once.

        Idempotent and re-entrancy-safe: however many times nested
        ``scope()`` exits and explicit calls race here, the registry is
        saved and the async generator shut down a single time.
        """
        with self._close_mu:
            if self._closed:
                return
            self._closed = True
        self.coordinator.close()

    def __enter__(self) -> "TuningSession":
        self._scope_ctx = self.scope()
        return self._scope_ctx.__enter__()

    def __exit__(self, *exc: Any) -> None:
        ctx, self._scope_ctx = self._scope_ctx, None
        ctx.__exit__(*exc)


# ========================================================== default session
_DEFAULT_SESSION: TuningSession | None = None
_DEFAULT_MU = threading.Lock()


def default_session() -> TuningSession:
    """The process-default session (``REPRO_TUNE_*``-configured, lazy)."""
    global _DEFAULT_SESSION
    with _DEFAULT_MU:
        if _DEFAULT_SESSION is None or _DEFAULT_SESSION.closed:
            _DEFAULT_SESSION = TuningSession(TuningConfig.from_env())
        return _DEFAULT_SESSION


def set_default_session(
        session: TuningSession | None) -> TuningSession | None:
    """Install (or clear, with ``None``) the process-default session."""
    global _DEFAULT_SESSION
    with _DEFAULT_MU:
        old, _DEFAULT_SESSION = _DEFAULT_SESSION, session
    return old


def tune(fn: Callable[..., Any] | None = None, *,
         session: TuningSession | None = None,
         **kwargs: Any) -> Any:
    """``repro.tune``: wrap a jax callable via the (default) session."""
    return (session or default_session()).tune(fn, **kwargs)


def tuned(*, session: TuningSession | None = None,
          **kwargs: Any) -> Callable[[Callable[..., Any]], TunedFunction]:
    """``@repro.tuned(space=...)``: decorator form of :func:`tune`."""
    def deco(fn: Callable[..., Any]) -> TunedFunction:
        return tune(fn, session=session, **kwargs)

    return deco
