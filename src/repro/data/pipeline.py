"""Deterministic synthetic data pipeline.

Produces sharded token batches with a seeded, restart-reproducible stream:
batch ``i`` is a pure function of (seed, i), so a job restarted from step N
regenerates exactly the batches ≥ N (fault-tolerance requirement). Supports
host-sharded loading: each data shard materializes only its slice.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab: int = 32000
    batch: int = 8
    seq_len: int = 128


class SyntheticLM:
    """Markov-ish synthetic tokens (not uniform noise, so loss can drop)."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg

    def batch_at(self, index: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        B, T = cfg.batch, cfg.seq_len
        # structured stream: tok_{t+1} = (a * tok_t + noise) % vocab
        a = 31
        toks = np.empty((B, T + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, B)
        noise = rng.integers(0, 7, (B, T))
        for t in range(T):
            toks[:, t + 1] = (a * toks[:, t] + noise[:, t]) % cfg.vocab
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


def batches_for(cfg: ModelConfig, shape: ShapeSpec, *, seed: int = 1234,
                start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
    """Model-aware stream (adds stub modality inputs where required)."""
    B, T = shape.global_batch, shape.seq_len
    T_text = T - cfg.vision_patches if cfg.family == "vlm" else T
    lm = SyntheticLM(DataConfig(seed=seed, vocab=cfg.vocab, batch=B,
                                seq_len=T_text))
    i = start_step
    while True:
        b = lm.batch_at(i)
        if cfg.family == "encdec":
            rng = np.random.default_rng((seed, i, 7))
            b["audio_embeds"] = rng.standard_normal(
                (B, cfg.enc_frames, cfg.d_model)).astype(np.float32) * 0.05
        if cfg.family == "vlm":
            rng = np.random.default_rng((seed, i, 9))
            b["vision"] = rng.standard_normal(
                (B, cfg.vision_patches, cfg.d_model)).astype(np.float32) * 0.05
        yield b
        i += 1


def device_put_batch(batch: dict, shardings: dict | None = None) -> dict:
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings else jnp.asarray(v)
        for k, v in batch.items()
    }
